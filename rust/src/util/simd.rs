//! Explicit-SIMD microkernel layer for the CPU backend's hot loops.
//!
//! The paper's building blocks (SpMM, SpMMᵀ, the Gram/SYRK inside
//! CholeskyQR2) are bandwidth-bound; what the scalar code leaves on the
//! table is *instruction* throughput in the register-blocked inner
//! loops. This module provides the small fixed vocabulary those loops
//! need — dot products over 1/2/4 right-hand columns, their gathered
//! (indexed) forms for CSR rows, and the elementwise `axpy`/`scal` —
//! as runtime-dispatched microkernels with three implementations:
//!
//! * a **scalar reference** (`reference`), written in a canonical
//!   lane-blocked order (4 independent accumulator lanes for f64, 8 for
//!   f32, no FMA, a fixed reduction tree);
//! * **AVX2** (`x86_64`), whose vector accumulators and extract-halves
//!   reductions reproduce the reference arithmetic *bitwise*;
//! * **NEON** (`aarch64`), using register pairs to model the same 4/8
//!   logical lanes and the same reduction tree, also bitwise-identical.
//!
//! Bitwise equality between `TRUNKSVD_SIMD=off` and every ISA path is a
//! hard invariant (pinned by `tests/test_simd_kernels.rs`): the SIMD
//! flag must never change a result, only its speed. That is why the
//! kernels avoid FMA — fused multiply-add contracts the rounding step
//! and would fork the bit patterns between paths.
//!
//! Dispatch: the active level is resolved once from `TRUNKSVD_SIMD`
//! (`auto` | `off` | `avx2` | `neon`, default `auto` = best detected)
//! and cached in a `OnceLock`; [`set_level`] installs a process-wide
//! override so benches and tests can sweep levels in-process. Requesting
//! an ISA the host lacks silently degrades to the scalar reference.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::util::scalar::Scalar;

/// Active microkernel implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Scalar reference path (canonical lane-blocked arithmetic).
    Off,
    /// AVX2 256-bit path (x86_64).
    Avx2,
    /// NEON 128-bit-pair path (aarch64).
    Neon,
}

impl SimdLevel {
    /// Name used in reports / `BENCH_kernels.json`.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Off => "off",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Parse a `TRUNKSVD_SIMD` value. `auto` (and anything unknown)
    /// maps to `None`, meaning "use the detected best level".
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "scalar" | "0" => Some(SimdLevel::Off),
            "avx2" => Some(SimdLevel::Avx2),
            "neon" => Some(SimdLevel::Neon),
            _ => None,
        }
    }
}

/// Best level supported by the running CPU, ignoring the environment
/// and any [`set_level`] override.
pub fn detected_level() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
        SimdLevel::Off
    }
    #[cfg(target_arch = "aarch64")]
    {
        SimdLevel::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        SimdLevel::Off
    }
}

/// Clamp a requested level to what the host can actually run.
fn supported(requested: SimdLevel) -> SimdLevel {
    match requested {
        SimdLevel::Off => SimdLevel::Off,
        SimdLevel::Avx2 => {
            if detected_level() == SimdLevel::Avx2 {
                SimdLevel::Avx2
            } else {
                SimdLevel::Off
            }
        }
        SimdLevel::Neon => {
            if detected_level() == SimdLevel::Neon {
                SimdLevel::Neon
            } else {
                SimdLevel::Off
            }
        }
    }
}

/// `TRUNKSVD_SIMD` default, resolved once.
fn env_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("TRUNKSVD_SIMD") {
        Ok(v) => match SimdLevel::parse(&v) {
            Some(l) => supported(l),
            None => detected_level(), // "auto" / unknown
        },
        Err(_) => detected_level(),
    })
}

/// Process-wide override installed by [`set_level`]:
/// 0 = none (env default), 1 = Off, 2 = Avx2, 3 = Neon.
static LEVEL_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the dispatch level for this process (benches/tests sweep
/// SIMD-off vs SIMD-on in-process with this). `None` restores the
/// `TRUNKSVD_SIMD` environment default. Requests for an unsupported ISA
/// degrade to `Off`.
pub fn set_level(level: Option<SimdLevel>) {
    let code = match level.map(supported) {
        None => 0,
        Some(SimdLevel::Off) => 1,
        Some(SimdLevel::Avx2) => 2,
        Some(SimdLevel::Neon) => 3,
    };
    LEVEL_OVERRIDE.store(code, Ordering::Relaxed);
}

/// The level kernels dispatch on right now.
pub fn level() -> SimdLevel {
    match LEVEL_OVERRIDE.load(Ordering::Relaxed) {
        1 => SimdLevel::Off,
        2 => SimdLevel::Avx2,
        3 => SimdLevel::Neon,
        _ => env_level(),
    }
}

/// AVX2's 32-bit gather indices are signed: fall back to the reference
/// path when a right-hand operand is long enough for u32 → i32
/// reinterpretation to go negative.
#[cfg(target_arch = "x86_64")]
const GATHER_MAX_LEN: usize = i32::MAX as usize;

// ---------------------------------------------------------------------
// Scalar reference: the canonical arithmetic every ISA must reproduce.
// ---------------------------------------------------------------------

/// Canonical lane-blocked scalar kernels. `L` is the logical lane count
/// (4 for f64, 8 for f32 — one 256-bit register). The reduction tree is
/// "fold the high half onto the low half, repeatedly", which is exactly
/// what the AVX2 extract/NEON pairwise reductions compute; the tail is
/// always *reduce lanes first, then accumulate the remainder serially*.
pub mod reference {
    use super::Scalar;

    #[inline(always)]
    #[allow(clippy::assign_op_pattern)] // `buf[l] = buf[l] + buf[l + h]` mirrors the ISA tree
    fn reduce<S: Scalar, const L: usize>(acc: [S; L]) -> S {
        let mut buf = acc;
        let mut h = L;
        while h > 1 {
            h /= 2;
            for l in 0..h {
                buf[l] = buf[l] + buf[l + h];
            }
        }
        buf[0]
    }

    #[inline]
    pub fn dot<S: Scalar, const L: usize>(x: &[S], y: &[S]) -> S {
        let n = x.len();
        debug_assert_eq!(n, y.len());
        let mut acc = [S::ZERO; L];
        let nl = n - n % L;
        let mut i = 0;
        while i < nl {
            for l in 0..L {
                acc[l] += x[i + l] * y[i + l];
            }
            i += L;
        }
        let mut s = reduce(acc);
        while i < n {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    #[inline]
    pub fn dot2<S: Scalar, const L: usize>(x0: &[S], x1: &[S], y: &[S]) -> (S, S) {
        let n = y.len();
        debug_assert!(x0.len() == n && x1.len() == n);
        let mut a0 = [S::ZERO; L];
        let mut a1 = [S::ZERO; L];
        let nl = n - n % L;
        let mut i = 0;
        while i < nl {
            for l in 0..L {
                let v = y[i + l];
                a0[l] += x0[i + l] * v;
                a1[l] += x1[i + l] * v;
            }
            i += L;
        }
        let mut s0 = reduce(a0);
        let mut s1 = reduce(a1);
        while i < n {
            let v = y[i];
            s0 += x0[i] * v;
            s1 += x1[i] * v;
            i += 1;
        }
        (s0, s1)
    }

    #[inline]
    #[allow(clippy::type_complexity)]
    pub fn dot4<S: Scalar, const L: usize>(
        w: &[S],
        x0: &[S],
        x1: &[S],
        x2: &[S],
        x3: &[S],
    ) -> (S, S, S, S) {
        let n = w.len();
        debug_assert!(x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n);
        let mut a0 = [S::ZERO; L];
        let mut a1 = [S::ZERO; L];
        let mut a2 = [S::ZERO; L];
        let mut a3 = [S::ZERO; L];
        let nl = n - n % L;
        let mut i = 0;
        while i < nl {
            for l in 0..L {
                let v = w[i + l];
                a0[l] += v * x0[i + l];
                a1[l] += v * x1[i + l];
                a2[l] += v * x2[i + l];
                a3[l] += v * x3[i + l];
            }
            i += L;
        }
        let mut s0 = reduce(a0);
        let mut s1 = reduce(a1);
        let mut s2 = reduce(a2);
        let mut s3 = reduce(a3);
        while i < n {
            let v = w[i];
            s0 += v * x0[i];
            s1 += v * x1[i];
            s2 += v * x2[i];
            s3 += v * x3[i];
            i += 1;
        }
        (s0, s1, s2, s3)
    }

    #[inline]
    pub fn gather_dot1<S: Scalar, const L: usize>(vals: &[S], idx: &[u32], x: &[S]) -> S {
        let n = vals.len();
        debug_assert_eq!(n, idx.len());
        let mut acc = [S::ZERO; L];
        let nl = n - n % L;
        let mut i = 0;
        while i < nl {
            for l in 0..L {
                acc[l] += vals[i + l] * x[idx[i + l] as usize];
            }
            i += L;
        }
        let mut s = reduce(acc);
        while i < n {
            s += vals[i] * x[idx[i] as usize];
            i += 1;
        }
        s
    }

    #[inline]
    pub fn gather_dot2<S: Scalar, const L: usize>(
        vals: &[S],
        idx: &[u32],
        x0: &[S],
        x1: &[S],
    ) -> (S, S) {
        let n = vals.len();
        debug_assert_eq!(n, idx.len());
        let mut a0 = [S::ZERO; L];
        let mut a1 = [S::ZERO; L];
        let nl = n - n % L;
        let mut i = 0;
        while i < nl {
            for l in 0..L {
                let c = idx[i + l] as usize;
                let v = vals[i + l];
                a0[l] += v * x0[c];
                a1[l] += v * x1[c];
            }
            i += L;
        }
        let mut s0 = reduce(a0);
        let mut s1 = reduce(a1);
        while i < n {
            let c = idx[i] as usize;
            let v = vals[i];
            s0 += v * x0[c];
            s1 += v * x1[c];
            i += 1;
        }
        (s0, s1)
    }

    #[inline]
    #[allow(clippy::type_complexity)]
    pub fn gather_dot4<S: Scalar, const L: usize>(
        vals: &[S],
        idx: &[u32],
        x0: &[S],
        x1: &[S],
        x2: &[S],
        x3: &[S],
    ) -> (S, S, S, S) {
        let n = vals.len();
        debug_assert_eq!(n, idx.len());
        let mut a0 = [S::ZERO; L];
        let mut a1 = [S::ZERO; L];
        let mut a2 = [S::ZERO; L];
        let mut a3 = [S::ZERO; L];
        let nl = n - n % L;
        let mut i = 0;
        while i < nl {
            for l in 0..L {
                let c = idx[i + l] as usize;
                let v = vals[i + l];
                a0[l] += v * x0[c];
                a1[l] += v * x1[c];
                a2[l] += v * x2[c];
                a3[l] += v * x3[c];
            }
            i += L;
        }
        let mut s0 = reduce(a0);
        let mut s1 = reduce(a1);
        let mut s2 = reduce(a2);
        let mut s3 = reduce(a3);
        while i < n {
            let c = idx[i] as usize;
            let v = vals[i];
            s0 += v * x0[c];
            s1 += v * x1[c];
            s2 += v * x2[c];
            s3 += v * x3[c];
            i += 1;
        }
        (s0, s1, s2, s3)
    }

    /// `y += a·x`. Elementwise (no reduction): any vector width computes
    /// identical bits, so this one form serves as reference for every
    /// ISA (given no FMA).
    #[inline]
    pub fn axpy<S: Scalar>(a: S, x: &[S], y: &mut [S]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, &xi) in y.iter_mut().zip(x.iter()) {
            *yi += a * xi;
        }
    }

    /// `x *= a`. Elementwise, like [`axpy`].
    #[inline]
    pub fn scal<S: Scalar>(a: S, x: &mut [S]) {
        for xi in x.iter_mut() {
            *xi *= a;
        }
    }
}

// ---------------------------------------------------------------------
// AVX2 (x86_64): 256-bit registers = 4×f64 / 8×f32 lanes.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Reduce 4 f64 lanes as `(a0+a2)+(a1+a3)` — the reference tree.
    ///
    /// # Safety
    /// Requires AVX2 (callers are `target_feature(avx2)` fns).
    #[target_feature(enable = "avx2")]
    unsafe fn reduce_pd(acc: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(acc); // [a0, a1]
        let hi = _mm256_extractf128_pd(acc, 1); // [a2, a3]
        let s = _mm_add_pd(lo, hi); // [a0+a2, a1+a3]
        _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
    }

    /// Reduce 8 f32 lanes as `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))`.
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn reduce_ps(acc: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(acc); // [a0..a3]
        let hi = _mm256_extractf128_ps(acc, 1); // [a4..a7]
        let s = _mm_add_ps(lo, hi); // [a0+a4, a1+a5, a2+a6, a3+a7]
        let s2 = _mm_add_ps(s, _mm_movehl_ps(s, s)); // lane0 = (a0+a4)+(a2+a6), lane1 = (a1+a5)+(a3+a7)
        _mm_cvtss_f32(_mm_add_ss(s2, _mm_movehdup_ps(s2)))
    }

    /// # Safety
    /// Requires AVX2; `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f64(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len();
        let nl = n - n % 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let vx = _mm256_loadu_pd(x.as_ptr().add(i));
            let vy = _mm256_loadu_pd(y.as_ptr().add(i));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(vx, vy));
            i += 4;
        }
        let mut s = reduce_pd(acc);
        while i < n {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Requires AVX2; `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len();
        let nl = n - n % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < nl {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(vx, vy));
            i += 8;
        }
        let mut s = reduce_ps(acc);
        while i < n {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Requires AVX2; all slices the same length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot2_f64(x0: &[f64], x1: &[f64], y: &[f64]) -> (f64, f64) {
        let n = y.len();
        let nl = n - n % 4;
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let vy = _mm256_loadu_pd(y.as_ptr().add(i));
            a0 = _mm256_add_pd(a0, _mm256_mul_pd(_mm256_loadu_pd(x0.as_ptr().add(i)), vy));
            a1 = _mm256_add_pd(a1, _mm256_mul_pd(_mm256_loadu_pd(x1.as_ptr().add(i)), vy));
            i += 4;
        }
        let mut s0 = reduce_pd(a0);
        let mut s1 = reduce_pd(a1);
        while i < n {
            let v = y[i];
            s0 += x0[i] * v;
            s1 += x1[i] * v;
            i += 1;
        }
        (s0, s1)
    }

    /// # Safety
    /// Requires AVX2; all slices the same length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot2_f32(x0: &[f32], x1: &[f32], y: &[f32]) -> (f32, f32) {
        let n = y.len();
        let nl = n - n % 8;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut i = 0;
        while i < nl {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_loadu_ps(x0.as_ptr().add(i)), vy));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_loadu_ps(x1.as_ptr().add(i)), vy));
            i += 8;
        }
        let mut s0 = reduce_ps(a0);
        let mut s1 = reduce_ps(a1);
        while i < n {
            let v = y[i];
            s0 += x0[i] * v;
            s1 += x1[i] * v;
            i += 1;
        }
        (s0, s1)
    }

    /// # Safety
    /// Requires AVX2; all slices the same length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4_f64(
        w: &[f64],
        x0: &[f64],
        x1: &[f64],
        x2: &[f64],
        x3: &[f64],
    ) -> (f64, f64, f64, f64) {
        let n = w.len();
        let nl = n - n % 4;
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut a2 = _mm256_setzero_pd();
        let mut a3 = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let vw = _mm256_loadu_pd(w.as_ptr().add(i));
            a0 = _mm256_add_pd(a0, _mm256_mul_pd(vw, _mm256_loadu_pd(x0.as_ptr().add(i))));
            a1 = _mm256_add_pd(a1, _mm256_mul_pd(vw, _mm256_loadu_pd(x1.as_ptr().add(i))));
            a2 = _mm256_add_pd(a2, _mm256_mul_pd(vw, _mm256_loadu_pd(x2.as_ptr().add(i))));
            a3 = _mm256_add_pd(a3, _mm256_mul_pd(vw, _mm256_loadu_pd(x3.as_ptr().add(i))));
            i += 4;
        }
        let mut s0 = reduce_pd(a0);
        let mut s1 = reduce_pd(a1);
        let mut s2 = reduce_pd(a2);
        let mut s3 = reduce_pd(a3);
        while i < n {
            let v = w[i];
            s0 += v * x0[i];
            s1 += v * x1[i];
            s2 += v * x2[i];
            s3 += v * x3[i];
            i += 1;
        }
        (s0, s1, s2, s3)
    }

    /// # Safety
    /// Requires AVX2; all slices the same length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4_f32(
        w: &[f32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let n = w.len();
        let nl = n - n % 8;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let mut i = 0;
        while i < nl {
            let vw = _mm256_loadu_ps(w.as_ptr().add(i));
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(vw, _mm256_loadu_ps(x0.as_ptr().add(i))));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(vw, _mm256_loadu_ps(x1.as_ptr().add(i))));
            a2 = _mm256_add_ps(a2, _mm256_mul_ps(vw, _mm256_loadu_ps(x2.as_ptr().add(i))));
            a3 = _mm256_add_ps(a3, _mm256_mul_ps(vw, _mm256_loadu_ps(x3.as_ptr().add(i))));
            i += 8;
        }
        let mut s0 = reduce_ps(a0);
        let mut s1 = reduce_ps(a1);
        let mut s2 = reduce_ps(a2);
        let mut s3 = reduce_ps(a3);
        while i < n {
            let v = w[i];
            s0 += v * x0[i];
            s1 += v * x1[i];
            s2 += v * x2[i];
            s3 += v * x3[i];
            i += 1;
        }
        (s0, s1, s2, s3)
    }

    /// # Safety
    /// Requires AVX2; `vals.len() == idx.len()`, every index in-bounds
    /// for `x`, and `x.len() <= i32::MAX` (checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_dot1_f64(vals: &[f64], idx: &[u32], x: &[f64]) -> f64 {
        let n = vals.len();
        let nl = n - n % 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let vv = _mm256_loadu_pd(vals.as_ptr().add(i));
            let vi = _mm_loadu_si128(idx.as_ptr().add(i) as *const __m128i);
            let g = _mm256_i32gather_pd::<8>(x.as_ptr(), vi);
            acc = _mm256_add_pd(acc, _mm256_mul_pd(vv, g));
            i += 4;
        }
        let mut s = reduce_pd(acc);
        while i < n {
            s += vals[i] * x[idx[i] as usize];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Same contract as [`gather_dot1_f64`], for f32 / 8 lanes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_dot1_f32(vals: &[f32], idx: &[u32], x: &[f32]) -> f32 {
        let n = vals.len();
        let nl = n - n % 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < nl {
            let vv = _mm256_loadu_ps(vals.as_ptr().add(i));
            let vi = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            let g = _mm256_i32gather_ps::<4>(x.as_ptr(), vi);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(vv, g));
            i += 8;
        }
        let mut s = reduce_ps(acc);
        while i < n {
            s += vals[i] * x[idx[i] as usize];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Same contract as [`gather_dot1_f64`], over two right-hand columns.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_dot2_f64(
        vals: &[f64],
        idx: &[u32],
        x0: &[f64],
        x1: &[f64],
    ) -> (f64, f64) {
        let n = vals.len();
        let nl = n - n % 4;
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let vv = _mm256_loadu_pd(vals.as_ptr().add(i));
            let vi = _mm_loadu_si128(idx.as_ptr().add(i) as *const __m128i);
            a0 = _mm256_add_pd(a0, _mm256_mul_pd(vv, _mm256_i32gather_pd::<8>(x0.as_ptr(), vi)));
            a1 = _mm256_add_pd(a1, _mm256_mul_pd(vv, _mm256_i32gather_pd::<8>(x1.as_ptr(), vi)));
            i += 4;
        }
        let mut s0 = reduce_pd(a0);
        let mut s1 = reduce_pd(a1);
        while i < n {
            let c = idx[i] as usize;
            let v = vals[i];
            s0 += v * x0[c];
            s1 += v * x1[c];
            i += 1;
        }
        (s0, s1)
    }

    /// # Safety
    /// Same contract as [`gather_dot1_f64`], for f32 over two columns.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_dot2_f32(
        vals: &[f32],
        idx: &[u32],
        x0: &[f32],
        x1: &[f32],
    ) -> (f32, f32) {
        let n = vals.len();
        let nl = n - n % 8;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut i = 0;
        while i < nl {
            let vv = _mm256_loadu_ps(vals.as_ptr().add(i));
            let vi = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(vv, _mm256_i32gather_ps::<4>(x0.as_ptr(), vi)));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(vv, _mm256_i32gather_ps::<4>(x1.as_ptr(), vi)));
            i += 8;
        }
        let mut s0 = reduce_ps(a0);
        let mut s1 = reduce_ps(a1);
        while i < n {
            let c = idx[i] as usize;
            let v = vals[i];
            s0 += v * x0[c];
            s1 += v * x1[c];
            i += 1;
        }
        (s0, s1)
    }

    /// # Safety
    /// Same contract as [`gather_dot1_f64`], over four right-hand columns.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_dot4_f64(
        vals: &[f64],
        idx: &[u32],
        x0: &[f64],
        x1: &[f64],
        x2: &[f64],
        x3: &[f64],
    ) -> (f64, f64, f64, f64) {
        let n = vals.len();
        let nl = n - n % 4;
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut a2 = _mm256_setzero_pd();
        let mut a3 = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let vv = _mm256_loadu_pd(vals.as_ptr().add(i));
            let vi = _mm_loadu_si128(idx.as_ptr().add(i) as *const __m128i);
            a0 = _mm256_add_pd(a0, _mm256_mul_pd(vv, _mm256_i32gather_pd::<8>(x0.as_ptr(), vi)));
            a1 = _mm256_add_pd(a1, _mm256_mul_pd(vv, _mm256_i32gather_pd::<8>(x1.as_ptr(), vi)));
            a2 = _mm256_add_pd(a2, _mm256_mul_pd(vv, _mm256_i32gather_pd::<8>(x2.as_ptr(), vi)));
            a3 = _mm256_add_pd(a3, _mm256_mul_pd(vv, _mm256_i32gather_pd::<8>(x3.as_ptr(), vi)));
            i += 4;
        }
        let mut s0 = reduce_pd(a0);
        let mut s1 = reduce_pd(a1);
        let mut s2 = reduce_pd(a2);
        let mut s3 = reduce_pd(a3);
        while i < n {
            let c = idx[i] as usize;
            let v = vals[i];
            s0 += v * x0[c];
            s1 += v * x1[c];
            s2 += v * x2[c];
            s3 += v * x3[c];
            i += 1;
        }
        (s0, s1, s2, s3)
    }

    /// # Safety
    /// Same contract as [`gather_dot1_f64`], for f32 over four columns.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_dot4_f32(
        vals: &[f32],
        idx: &[u32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let n = vals.len();
        let nl = n - n % 8;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let mut i = 0;
        while i < nl {
            let vv = _mm256_loadu_ps(vals.as_ptr().add(i));
            let vi = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(vv, _mm256_i32gather_ps::<4>(x0.as_ptr(), vi)));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(vv, _mm256_i32gather_ps::<4>(x1.as_ptr(), vi)));
            a2 = _mm256_add_ps(a2, _mm256_mul_ps(vv, _mm256_i32gather_ps::<4>(x2.as_ptr(), vi)));
            a3 = _mm256_add_ps(a3, _mm256_mul_ps(vv, _mm256_i32gather_ps::<4>(x3.as_ptr(), vi)));
            i += 8;
        }
        let mut s0 = reduce_ps(a0);
        let mut s1 = reduce_ps(a1);
        let mut s2 = reduce_ps(a2);
        let mut s3 = reduce_ps(a3);
        while i < n {
            let c = idx[i] as usize;
            let v = vals[i];
            s0 += v * x0[c];
            s1 += v * x1[c];
            s2 += v * x2[c];
            s3 += v * x3[c];
            i += 1;
        }
        (s0, s1, s2, s3)
    }

    /// # Safety
    /// Requires AVX2; `x.len() == y.len()`. No FMA, so bitwise equal to
    /// the scalar form per element.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f64(a: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let nl = n - n % 4;
        let va = _mm256_set1_pd(a);
        let mut i = 0;
        while i < nl {
            let vx = _mm256_loadu_pd(x.as_ptr().add(i));
            let vy = _mm256_loadu_pd(y.as_ptr().add(i));
            _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
            i += 4;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    /// # Safety
    /// Requires AVX2; `x.len() == y.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let nl = n - n % 8;
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i < nl {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
            i += 8;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scal_f64(a: f64, x: &mut [f64]) {
        let n = x.len();
        let nl = n - n % 4;
        let va = _mm256_set1_pd(a);
        let mut i = 0;
        while i < nl {
            let vx = _mm256_loadu_pd(x.as_ptr().add(i));
            _mm256_storeu_pd(x.as_mut_ptr().add(i), _mm256_mul_pd(vx, va));
            i += 4;
        }
        while i < n {
            x[i] *= a;
            i += 1;
        }
    }

    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scal_f32(a: f32, x: &mut [f32]) {
        let n = x.len();
        let nl = n - n % 8;
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i < nl {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_mul_ps(vx, va));
            i += 8;
        }
        while i < n {
            x[i] *= a;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------
// NEON (aarch64): 128-bit register *pairs* model the same 4 f64 / 8 f32
// logical lanes, so the reductions land on the identical tree. NEON has
// no hardware gather; the gathered forms use the scalar reference,
// which is bitwise-identical by construction.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// # Safety
    /// `x.len() == y.len()`. NEON is baseline on aarch64.
    pub unsafe fn dot_f64(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len();
        let nl = n - n % 4;
        let mut a01 = vdupq_n_f64(0.0); // lanes 0,1
        let mut a23 = vdupq_n_f64(0.0); // lanes 2,3
        let mut i = 0;
        while i < nl {
            let x01 = vld1q_f64(x.as_ptr().add(i));
            let x23 = vld1q_f64(x.as_ptr().add(i + 2));
            let y01 = vld1q_f64(y.as_ptr().add(i));
            let y23 = vld1q_f64(y.as_ptr().add(i + 2));
            a01 = vaddq_f64(a01, vmulq_f64(x01, y01));
            a23 = vaddq_f64(a23, vmulq_f64(x23, y23));
            i += 4;
        }
        // [a0+a2, a1+a3] then lane0 + lane1: the reference tree.
        let p = vaddq_f64(a01, a23);
        let mut s = vgetq_lane_f64::<0>(p) + vgetq_lane_f64::<1>(p);
        while i < n {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// `x.len() == y.len()`.
    pub unsafe fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len();
        let nl = n - n % 8;
        let mut a03 = vdupq_n_f32(0.0); // lanes 0..3
        let mut a47 = vdupq_n_f32(0.0); // lanes 4..7
        let mut i = 0;
        while i < nl {
            let x03 = vld1q_f32(x.as_ptr().add(i));
            let x47 = vld1q_f32(x.as_ptr().add(i + 4));
            let y03 = vld1q_f32(y.as_ptr().add(i));
            let y47 = vld1q_f32(y.as_ptr().add(i + 4));
            a03 = vaddq_f32(a03, vmulq_f32(x03, y03));
            a47 = vaddq_f32(a47, vmulq_f32(x47, y47));
            i += 8;
        }
        // [a0+a4, a1+a5, a2+a6, a3+a7], fold high pair onto low pair,
        // then lane0 + lane1: the reference tree.
        let q = vaddq_f32(a03, a47);
        let d = vadd_f32(vget_low_f32(q), vget_high_f32(q));
        let mut s = vget_lane_f32::<0>(d) + vget_lane_f32::<1>(d);
        while i < n {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// All slices the same length.
    pub unsafe fn dot2_f64(x0: &[f64], x1: &[f64], y: &[f64]) -> (f64, f64) {
        let n = y.len();
        let nl = n - n % 4;
        let mut a0_01 = vdupq_n_f64(0.0);
        let mut a0_23 = vdupq_n_f64(0.0);
        let mut a1_01 = vdupq_n_f64(0.0);
        let mut a1_23 = vdupq_n_f64(0.0);
        let mut i = 0;
        while i < nl {
            let y01 = vld1q_f64(y.as_ptr().add(i));
            let y23 = vld1q_f64(y.as_ptr().add(i + 2));
            a0_01 = vaddq_f64(a0_01, vmulq_f64(vld1q_f64(x0.as_ptr().add(i)), y01));
            a0_23 = vaddq_f64(a0_23, vmulq_f64(vld1q_f64(x0.as_ptr().add(i + 2)), y23));
            a1_01 = vaddq_f64(a1_01, vmulq_f64(vld1q_f64(x1.as_ptr().add(i)), y01));
            a1_23 = vaddq_f64(a1_23, vmulq_f64(vld1q_f64(x1.as_ptr().add(i + 2)), y23));
            i += 4;
        }
        let p0 = vaddq_f64(a0_01, a0_23);
        let p1 = vaddq_f64(a1_01, a1_23);
        let mut s0 = vgetq_lane_f64::<0>(p0) + vgetq_lane_f64::<1>(p0);
        let mut s1 = vgetq_lane_f64::<0>(p1) + vgetq_lane_f64::<1>(p1);
        while i < n {
            let v = y[i];
            s0 += x0[i] * v;
            s1 += x1[i] * v;
            i += 1;
        }
        (s0, s1)
    }

    /// # Safety
    /// All slices the same length.
    pub unsafe fn dot2_f32(x0: &[f32], x1: &[f32], y: &[f32]) -> (f32, f32) {
        let n = y.len();
        let nl = n - n % 8;
        let mut a0_03 = vdupq_n_f32(0.0);
        let mut a0_47 = vdupq_n_f32(0.0);
        let mut a1_03 = vdupq_n_f32(0.0);
        let mut a1_47 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < nl {
            let y03 = vld1q_f32(y.as_ptr().add(i));
            let y47 = vld1q_f32(y.as_ptr().add(i + 4));
            a0_03 = vaddq_f32(a0_03, vmulq_f32(vld1q_f32(x0.as_ptr().add(i)), y03));
            a0_47 = vaddq_f32(a0_47, vmulq_f32(vld1q_f32(x0.as_ptr().add(i + 4)), y47));
            a1_03 = vaddq_f32(a1_03, vmulq_f32(vld1q_f32(x1.as_ptr().add(i)), y03));
            a1_47 = vaddq_f32(a1_47, vmulq_f32(vld1q_f32(x1.as_ptr().add(i + 4)), y47));
            i += 8;
        }
        let q0 = vaddq_f32(a0_03, a0_47);
        let q1 = vaddq_f32(a1_03, a1_47);
        let d0 = vadd_f32(vget_low_f32(q0), vget_high_f32(q0));
        let d1 = vadd_f32(vget_low_f32(q1), vget_high_f32(q1));
        let mut s0 = vget_lane_f32::<0>(d0) + vget_lane_f32::<1>(d0);
        let mut s1 = vget_lane_f32::<0>(d1) + vget_lane_f32::<1>(d1);
        while i < n {
            let v = y[i];
            s0 += x0[i] * v;
            s1 += x1[i] * v;
            i += 1;
        }
        (s0, s1)
    }

    /// # Safety
    /// All slices the same length.
    pub unsafe fn dot4_f64(
        w: &[f64],
        x0: &[f64],
        x1: &[f64],
        x2: &[f64],
        x3: &[f64],
    ) -> (f64, f64, f64, f64) {
        let n = w.len();
        let nl = n - n % 4;
        let mut acc = [[vdupq_n_f64(0.0); 2]; 4];
        let xs = [x0, x1, x2, x3];
        let mut i = 0;
        while i < nl {
            let w01 = vld1q_f64(w.as_ptr().add(i));
            let w23 = vld1q_f64(w.as_ptr().add(i + 2));
            for (j, xj) in xs.iter().enumerate() {
                acc[j][0] = vaddq_f64(acc[j][0], vmulq_f64(w01, vld1q_f64(xj.as_ptr().add(i))));
                acc[j][1] =
                    vaddq_f64(acc[j][1], vmulq_f64(w23, vld1q_f64(xj.as_ptr().add(i + 2))));
            }
            i += 4;
        }
        let mut s = [0.0f64; 4];
        for j in 0..4 {
            let p = vaddq_f64(acc[j][0], acc[j][1]);
            s[j] = vgetq_lane_f64::<0>(p) + vgetq_lane_f64::<1>(p);
        }
        while i < n {
            let v = w[i];
            for (j, xj) in xs.iter().enumerate() {
                s[j] += v * xj[i];
            }
            i += 1;
        }
        (s[0], s[1], s[2], s[3])
    }

    /// # Safety
    /// All slices the same length.
    pub unsafe fn dot4_f32(
        w: &[f32],
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
    ) -> (f32, f32, f32, f32) {
        let n = w.len();
        let nl = n - n % 8;
        let mut acc = [[vdupq_n_f32(0.0); 2]; 4];
        let xs = [x0, x1, x2, x3];
        let mut i = 0;
        while i < nl {
            let w03 = vld1q_f32(w.as_ptr().add(i));
            let w47 = vld1q_f32(w.as_ptr().add(i + 4));
            for (j, xj) in xs.iter().enumerate() {
                acc[j][0] = vaddq_f32(acc[j][0], vmulq_f32(w03, vld1q_f32(xj.as_ptr().add(i))));
                acc[j][1] =
                    vaddq_f32(acc[j][1], vmulq_f32(w47, vld1q_f32(xj.as_ptr().add(i + 4))));
            }
            i += 8;
        }
        let mut s = [0.0f32; 4];
        for j in 0..4 {
            let q = vaddq_f32(acc[j][0], acc[j][1]);
            let d = vadd_f32(vget_low_f32(q), vget_high_f32(q));
            s[j] = vget_lane_f32::<0>(d) + vget_lane_f32::<1>(d);
        }
        while i < n {
            let v = w[i];
            for (j, xj) in xs.iter().enumerate() {
                s[j] += v * xj[i];
            }
            i += 1;
        }
        (s[0], s[1], s[2], s[3])
    }

    /// # Safety
    /// `x.len() == y.len()`.
    pub unsafe fn axpy_f64(a: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let nl = n - n % 2;
        let va = vdupq_n_f64(a);
        let mut i = 0;
        while i < nl {
            let vx = vld1q_f64(x.as_ptr().add(i));
            let vy = vld1q_f64(y.as_ptr().add(i));
            vst1q_f64(y.as_mut_ptr().add(i), vaddq_f64(vy, vmulq_f64(va, vx)));
            i += 2;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    /// # Safety
    /// `x.len() == y.len()`.
    pub unsafe fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let nl = n - n % 4;
        let va = vdupq_n_f32(a);
        let mut i = 0;
        while i < nl {
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vy = vld1q_f32(y.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vmulq_f32(va, vx)));
            i += 4;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    /// # Safety
    /// Plain elementwise scale.
    pub unsafe fn scal_f64(a: f64, x: &mut [f64]) {
        let n = x.len();
        let nl = n - n % 2;
        let va = vdupq_n_f64(a);
        let mut i = 0;
        while i < nl {
            let vx = vld1q_f64(x.as_ptr().add(i));
            vst1q_f64(x.as_mut_ptr().add(i), vmulq_f64(vx, va));
            i += 2;
        }
        while i < n {
            x[i] *= a;
            i += 1;
        }
    }

    /// # Safety
    /// Plain elementwise scale.
    pub unsafe fn scal_f32(a: f32, x: &mut [f32]) {
        let n = x.len();
        let nl = n - n % 4;
        let va = vdupq_n_f32(a);
        let mut i = 0;
        while i < nl {
            let vx = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(x.as_mut_ptr().add(i), vmulq_f32(vx, va));
            i += 4;
        }
        while i < n {
            x[i] *= a;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------
// Dispatchers (one per concrete type × kernel). The `Scalar` trait's
// `simd_*` methods forward here; generic kernel code never names an ISA.
// ---------------------------------------------------------------------

macro_rules! dispatch {
    // Non-gather kernels: every level has an impl on its own arch.
    ($lvl:expr => avx2 $ax:expr, neon $ne:expr, ref $rf:expr) => {{
        match $lvl {
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => unsafe { $ax },
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => unsafe { $ne },
            _ => $rf,
        }
    }};
}

pub fn dot_f64(x: &[f64], y: &[f64]) -> f64 {
    dispatch!(level() => avx2 avx2::dot_f64(x, y), neon neon::dot_f64(x, y),
              ref reference::dot::<f64, 4>(x, y))
}

pub fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    dispatch!(level() => avx2 avx2::dot_f32(x, y), neon neon::dot_f32(x, y),
              ref reference::dot::<f32, 8>(x, y))
}

pub fn dot2_f64(x0: &[f64], x1: &[f64], y: &[f64]) -> (f64, f64) {
    dispatch!(level() => avx2 avx2::dot2_f64(x0, x1, y), neon neon::dot2_f64(x0, x1, y),
              ref reference::dot2::<f64, 4>(x0, x1, y))
}

pub fn dot2_f32(x0: &[f32], x1: &[f32], y: &[f32]) -> (f32, f32) {
    dispatch!(level() => avx2 avx2::dot2_f32(x0, x1, y), neon neon::dot2_f32(x0, x1, y),
              ref reference::dot2::<f32, 8>(x0, x1, y))
}

pub fn dot4_f64(w: &[f64], x0: &[f64], x1: &[f64], x2: &[f64], x3: &[f64]) -> (f64, f64, f64, f64) {
    dispatch!(level() => avx2 avx2::dot4_f64(w, x0, x1, x2, x3),
              neon neon::dot4_f64(w, x0, x1, x2, x3),
              ref reference::dot4::<f64, 4>(w, x0, x1, x2, x3))
}

pub fn dot4_f32(w: &[f32], x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32]) -> (f32, f32, f32, f32) {
    dispatch!(level() => avx2 avx2::dot4_f32(w, x0, x1, x2, x3),
              neon neon::dot4_f32(w, x0, x1, x2, x3),
              ref reference::dot4::<f32, 8>(w, x0, x1, x2, x3))
}

pub fn gather_dot1_f64(vals: &[f64], idx: &[u32], x: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if level() == SimdLevel::Avx2 && x.len() <= GATHER_MAX_LEN {
        return unsafe { avx2::gather_dot1_f64(vals, idx, x) };
    }
    reference::gather_dot1::<f64, 4>(vals, idx, x)
}

pub fn gather_dot1_f32(vals: &[f32], idx: &[u32], x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if level() == SimdLevel::Avx2 && x.len() <= GATHER_MAX_LEN {
        return unsafe { avx2::gather_dot1_f32(vals, idx, x) };
    }
    reference::gather_dot1::<f32, 8>(vals, idx, x)
}

pub fn gather_dot2_f64(vals: &[f64], idx: &[u32], x0: &[f64], x1: &[f64]) -> (f64, f64) {
    #[cfg(target_arch = "x86_64")]
    if level() == SimdLevel::Avx2 && x0.len() <= GATHER_MAX_LEN {
        return unsafe { avx2::gather_dot2_f64(vals, idx, x0, x1) };
    }
    reference::gather_dot2::<f64, 4>(vals, idx, x0, x1)
}

pub fn gather_dot2_f32(vals: &[f32], idx: &[u32], x0: &[f32], x1: &[f32]) -> (f32, f32) {
    #[cfg(target_arch = "x86_64")]
    if level() == SimdLevel::Avx2 && x0.len() <= GATHER_MAX_LEN {
        return unsafe { avx2::gather_dot2_f32(vals, idx, x0, x1) };
    }
    reference::gather_dot2::<f32, 8>(vals, idx, x0, x1)
}

pub fn gather_dot4_f64(
    vals: &[f64],
    idx: &[u32],
    x0: &[f64],
    x1: &[f64],
    x2: &[f64],
    x3: &[f64],
) -> (f64, f64, f64, f64) {
    #[cfg(target_arch = "x86_64")]
    if level() == SimdLevel::Avx2 && x0.len() <= GATHER_MAX_LEN {
        return unsafe { avx2::gather_dot4_f64(vals, idx, x0, x1, x2, x3) };
    }
    reference::gather_dot4::<f64, 4>(vals, idx, x0, x1, x2, x3)
}

pub fn gather_dot4_f32(
    vals: &[f32],
    idx: &[u32],
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x3: &[f32],
) -> (f32, f32, f32, f32) {
    #[cfg(target_arch = "x86_64")]
    if level() == SimdLevel::Avx2 && x0.len() <= GATHER_MAX_LEN {
        return unsafe { avx2::gather_dot4_f32(vals, idx, x0, x1, x2, x3) };
    }
    reference::gather_dot4::<f32, 8>(vals, idx, x0, x1, x2, x3)
}

pub fn axpy_f64(a: f64, x: &[f64], y: &mut [f64]) {
    dispatch!(level() => avx2 avx2::axpy_f64(a, x, y), neon neon::axpy_f64(a, x, y),
              ref reference::axpy(a, x, y))
}

pub fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    dispatch!(level() => avx2 avx2::axpy_f32(a, x, y), neon neon::axpy_f32(a, x, y),
              ref reference::axpy(a, x, y))
}

pub fn scal_f64(a: f64, x: &mut [f64]) {
    dispatch!(level() => avx2 avx2::scal_f64(a, x), neon neon::scal_f64(a, x),
              ref reference::scal(a, x))
}

pub fn scal_f32(a: f32, x: &mut [f32]) {
    dispatch!(level() => avx2 avx2::scal_f32(a, x), neon neon::scal_f32(a, x),
              ref reference::scal(a, x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Mutex;

    /// Tests that move the dispatch level serialize here and restore
    /// the env default before returning.
    static LEVEL_LOCK: Mutex<()> = Mutex::new(());

    struct LevelReset;
    impl Drop for LevelReset {
        fn drop(&mut self) {
            set_level(None);
        }
    }

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn parse_and_names() {
        assert_eq!(SimdLevel::parse("off"), Some(SimdLevel::Off));
        assert_eq!(SimdLevel::parse(" AVX2 "), Some(SimdLevel::Avx2));
        assert_eq!(SimdLevel::parse("neon"), Some(SimdLevel::Neon));
        assert_eq!(SimdLevel::parse("auto"), None);
        assert_eq!(SimdLevel::parse("bogus"), None);
        assert_eq!(SimdLevel::Off.name(), "off");
    }

    #[test]
    fn unsupported_isa_degrades_to_off() {
        // At most one of the two ISAs is the host's; the other must
        // clamp to Off instead of dispatching into missing intrinsics.
        let foreign = match detected_level() {
            SimdLevel::Neon => SimdLevel::Avx2,
            _ => SimdLevel::Neon,
        };
        assert_eq!(super::supported(foreign), SimdLevel::Off);
    }

    /// Every kernel, every tail length, both dtypes: the detected ISA
    /// path must be bitwise-identical to the scalar reference.
    #[test]
    fn isa_paths_match_reference_bitwise() {
        let _guard = LEVEL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let _reset = LevelReset;
        let best = detected_level();
        let cols = 512;
        for n in [0usize, 1, 3, 4, 7, 8, 9, 16, 31, 64, 129] {
            let w = randvec(n, 1);
            let xs: Vec<Vec<f64>> = (0..4).map(|j| randvec(n, 10 + j)).collect();
            let big: Vec<Vec<f64>> = (0..4).map(|j| randvec(cols, 20 + j)).collect();
            let mut rng = Rng::new(n as u64 + 99);
            let idx: Vec<u32> = (0..n).map(|_| rng.below(cols) as u32).collect();
            let wf: Vec<f32> = w.iter().map(|&v| v as f32).collect();
            let xf: Vec<Vec<f32>> =
                xs.iter().map(|c| c.iter().map(|&v| v as f32).collect()).collect();
            let bf: Vec<Vec<f32>> =
                big.iter().map(|c| c.iter().map(|&v| v as f32).collect()).collect();

            set_level(Some(SimdLevel::Off));
            let d_off = dot_f64(&w, &xs[0]);
            let d2_off = dot2_f64(&xs[0], &xs[1], &w);
            let d4_off = dot4_f64(&w, &xs[0], &xs[1], &xs[2], &xs[3]);
            let g1_off = gather_dot1_f64(&w, &idx, &big[0]);
            let g2_off = gather_dot2_f64(&w, &idx, &big[0], &big[1]);
            let g4_off = gather_dot4_f64(&w, &idx, &big[0], &big[1], &big[2], &big[3]);
            let df_off = dot_f32(&wf, &xf[0]);
            let d4f_off = dot4_f32(&wf, &xf[0], &xf[1], &xf[2], &xf[3]);
            let g4f_off = gather_dot4_f32(&wf, &idx, &bf[0], &bf[1], &bf[2], &bf[3]);
            let mut y_off = randvec(n, 500);
            axpy_f64(0.37, &w, &mut y_off);
            let mut z_off = randvec(n, 501);
            scal_f64(-1.25, &mut z_off);

            set_level(Some(best));
            assert_eq!(d_off.to_bits(), dot_f64(&w, &xs[0]).to_bits(), "dot n={n}");
            let d2 = dot2_f64(&xs[0], &xs[1], &w);
            assert_eq!((d2_off.0.to_bits(), d2_off.1.to_bits()), (d2.0.to_bits(), d2.1.to_bits()));
            let d4 = dot4_f64(&w, &xs[0], &xs[1], &xs[2], &xs[3]);
            assert_eq!(d4_off.0.to_bits(), d4.0.to_bits(), "dot4.0 n={n}");
            assert_eq!(d4_off.3.to_bits(), d4.3.to_bits(), "dot4.3 n={n}");
            assert_eq!(g1_off.to_bits(), gather_dot1_f64(&w, &idx, &big[0]).to_bits());
            let g2 = gather_dot2_f64(&w, &idx, &big[0], &big[1]);
            assert_eq!(g2_off.1.to_bits(), g2.1.to_bits(), "gather2 n={n}");
            let g4 = gather_dot4_f64(&w, &idx, &big[0], &big[1], &big[2], &big[3]);
            assert_eq!(g4_off.0.to_bits(), g4.0.to_bits(), "gather4.0 n={n}");
            assert_eq!(g4_off.2.to_bits(), g4.2.to_bits(), "gather4.2 n={n}");
            assert_eq!(df_off.to_bits(), dot_f32(&wf, &xf[0]).to_bits(), "dot f32 n={n}");
            let d4f = dot4_f32(&wf, &xf[0], &xf[1], &xf[2], &xf[3]);
            assert_eq!(d4f_off.1.to_bits(), d4f.1.to_bits(), "dot4 f32 n={n}");
            let g4f = gather_dot4_f32(&wf, &idx, &bf[0], &bf[1], &bf[2], &bf[3]);
            assert_eq!(g4f_off.3.to_bits(), g4f.3.to_bits(), "gather4 f32 n={n}");
            let mut y_on = randvec(n, 500);
            axpy_f64(0.37, &w, &mut y_on);
            assert_eq!(
                y_off.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y_on.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "axpy n={n}"
            );
            let mut z_on = randvec(n, 501);
            scal_f64(-1.25, &mut z_on);
            assert_eq!(
                z_off.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                z_on.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "scal n={n}"
            );
        }
    }

    /// Gathered forms with repeated indices (CSR rows can't repeat a
    /// column, but the microkernel contract shouldn't depend on it).
    #[test]
    fn gather_handles_duplicate_indices() {
        let vals = [1.0f64, 2.0, 3.0, 4.0, 5.0];
        let idx = [3u32, 3, 0, 1, 3];
        let x = [10.0f64, 20.0, 30.0, 40.0];
        let expect = 1.0 * 40.0 + 2.0 * 40.0 + 3.0 * 10.0 + 4.0 * 20.0 + 5.0 * 40.0;
        let got = gather_dot1_f64(&vals, &idx, &x);
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
    }

    /// dot against a naive sequential sum — value-level, not bitwise
    /// (the lane-blocked order differs from naive order by design).
    #[test]
    fn dot_matches_naive_to_tolerance() {
        let x = randvec(257, 7);
        let y = randvec(257, 8);
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot_f64(&x, &y) - naive).abs() < 1e-10 * x.len() as f64);
    }

    #[test]
    fn set_level_roundtrip() {
        let _guard = LEVEL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let _reset = LevelReset;
        set_level(Some(SimdLevel::Off));
        assert_eq!(level(), SimdLevel::Off);
        set_level(None);
        assert_eq!(level(), env_level());
    }
}
