//! Minimal JSON parser + writer.
//!
//! The offline vendor set has no `serde`/`serde_json`, so this module
//! implements the small subset of JSON we need for `config/suite.json`,
//! the artifact manifest, and machine-readable reports: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` access that flows `None` through.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Required-field access with a parse error on absence.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or(Error::Parse {
            what: "json",
            detail: format!("missing field '{key}'"),
        })
    }
}

fn perr(detail: impl Into<String>) -> Error {
    Error::Parse { what: "json", detail: detail.into() }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(perr(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek().ok_or_else(|| perr("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(perr(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(perr(format!("expected ',' or '}}' at byte {}", self.i))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(perr(format!("expected ',' or ']' at byte {}", self.i))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| perr("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| perr("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| perr("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| perr("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| perr("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(perr("unknown escape")),
                    }
                }
                _ => {
                    // Re-validate multibyte UTF-8 by slicing from the raw buffer.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let s = self
                            .b
                            .get(start..start + len)
                            .and_then(|s| std::str::from_utf8(s).ok())
                            .ok_or_else(|| perr("bad utf8"))?;
                        out.push_str(s);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| perr("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| perr(format!("bad number '{s}'")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(perr(format!("trailing data at byte {}", p.i)));
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).map_err(|e| Error::Io {
        path: path.to_string(),
        source: e,
    })?;
    parse(&text)
}

/// Serialize a JSON value (compact).
pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience constructors for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn str(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let doc = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\ny"));
        let text = write(&v);
        let v2 = parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn nested_and_empty() {
        let v = parse(r#"{"o": {}, "a": [], "n": [{"k": [1]}]}"#).unwrap();
        assert!(v.get("o").unwrap().as_obj().unwrap().is_empty());
        assert!(v.get("a").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(
            v.get("n").unwrap().as_arr().unwrap()[0]
                .get("k")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_usize(),
            Some(1)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn parses_suite_config() {
        // The checked-in experiment config must always parse.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/config/suite.json");
        let v = parse_file(path).unwrap();
        assert_eq!(v.get("sparse").unwrap().as_arr().unwrap().len(), 46);
        assert_eq!(v.get("dense").unwrap().as_arr().unwrap().len(), 4);
    }
}
