//! Persistent data-parallel worker pool.
//!
//! No rayon/tokio in the offline vendor set, so the hot loops run on this
//! hand-rolled threading layer. Through PR 2 the helpers here spawned
//! fresh scoped threads on *every* call; the paper's pipeline invokes the
//! hot kernels (SpMM, SpMMᵀ, Gram/SYRK, the CholeskyQR2 GEMMs) dozens of
//! times per Lanczos/randSVD iteration on small-to-medium panels, so the
//! per-call spawn cost was exactly the launch overhead the paper's GPU
//! kernels avoid by reusing device resources. The pool is now
//! *persistent*: N long-lived workers parked on a condvar, woken by a
//! generation-stamped job broadcast, with the calling thread executing
//! band 0 itself (measured by `bench_blocks` as `pool_dispatch_ns`).
//!
//! ## Worker lifecycle
//!
//! The pool is a process-global singleton, lazily initialized on the
//! first parallel call that wants more than one band. Workers are spawned
//! on demand up to `num_threads() - 1` (the submitter is the remaining
//! band) and then live for the rest of the process, parked in
//! `Condvar::wait` between jobs. A *job* is one `&dyn Fn(usize)` closure
//! broadcast under a fresh generation stamp: worker `w` wakes, runs
//! `job(w)` exactly once for its own band index, decrements the
//! outstanding-band count, and goes back to sleep. The submitting thread
//! runs band 0 (and any band that could not get a worker) inline, then
//! blocks until the count hits zero, so the closure — which borrows the
//! caller's stack — never outlives the call. Submissions are serialized
//! on a submit lock; concurrent callers (e.g. the adaptive-transpose
//! background build racing the foreground iteration) queue up rather than
//! interleave bands.
//!
//! ## Band affinity and worker pinning (NUMA model)
//!
//! Work is split *statically*: band `w` of a given `(n, threads)`
//! partition is always the same index range and always runs on the same
//! long-lived worker thread (band 0 on the caller). Repeated SpMM/Gram
//! calls on the same operand therefore re-touch the same row bands on the
//! same OS thread call after call. Static partitioning also makes every
//! helper deterministic: a fixed `(n, num_threads, parallel_cutoff)`
//! triple yields bitwise-identical results call after call (pinned by
//! the determinism sweep in `tests/test_threaded_kernels.rs`).
//!
//! Through PR 5 that affinity was *advisory*: the OS scheduler was free
//! to migrate a worker, dragging its warm cache lines and — worse — its
//! first-touched pages (the steady-state buffers of PR 4 are touched
//! first by the band that owns them, so they are resident on that
//! band's NUMA node) to a remote node. `TRUNKSVD_PIN` upgrades it to
//! enforced placement, in three levels:
//!
//! * `off` (default) — no syscalls; scheduler placement, as before.
//!   The default because CI runners and oversubscribed hosts degrade
//!   badly when pinned threads fight unrelated load for one core.
//! * `core` — worker `w` is pinned to exactly one CPU
//!   (`sched_setaffinity`, Linux only; a no-op elsewhere). Bands are
//!   dealt to the flattened, node-ordered CPU list round-robin, so
//!   consecutive bands fill one NUMA node's cores before spilling to
//!   the next: a band and the pages it first-touched stay node-local,
//!   and the L1/L2 a band warmed stays *its* L1/L2.
//! * `node` — worker `w` may float over all CPUs of its assigned NUMA
//!   node (same node-ordered assignment, looser mask): keeps the
//!   memory-locality benefit while tolerating core-level load spikes.
//!
//! Node topology comes from `/sys/devices/system/node/node*/cpulist`,
//! with a single synthetic node (all CPUs) as the fallback on
//! non-Linux / non-NUMA hosts. Only spawned workers are pinned; band 0
//! runs on the submitting thread, which belongs to the caller and is
//! never touched. Pin failures are silently ignored (the thread just
//! stays unpinned) — pinning is a performance hint, never a
//! correctness dependency.
//!
//! Alongside pinning, the band *partition* itself is cacheable:
//! [`parallel_row_blocks_bounds`] accepts a precomputed bounds vector,
//! which `sparse::csr` memoizes per `(operand identity, band count)` so
//! repeat solves against the same matrix skip the nnz-balancing scan
//! (see `csr::band_plan`).
//!
//! ## Serial fast path
//!
//! Threading only pays once a band amortizes the wake/join handshake.
//! The slice-partitioned helpers divide a *work estimate* — the total
//! scalar elements the call will touch, defaulting to the output size
//! and overridden by the kernels via the `*_work` variants when the
//! true cost is operand-dominated (nnz for SpMM, rows·b for the SYRK) —
//! by [`parallel_cutoff`] (default from [`crate::cost::parallel_cutoff`],
//! overridable via `TRUNKSVD_PARALLEL_CUTOFF` or
//! [`set_parallel_cutoff`]) to choose the band count; small panels fall
//! through to a plain serial loop without touching the pool at all.
//! [`parallel_for`] and [`parallel_tasks`] are coarse-task APIs (one
//! index may hide arbitrary work), so they fan out whenever `n >= 2` and
//! more than one thread is configured.
//!
//! ## Resize semantics
//!
//! [`set_num_threads`] may be called at any time from any thread that is
//! not itself inside a pool job. Growing spawns the missing workers on
//! the next broadcast; shrinking simply stops handing bands to the
//! excess workers, which keep sleeping (worker threads are never torn
//! down mid-process — parked threads cost a stack apiece and nothing
//! else). In-flight jobs always finish on the thread set they started
//! with; the new count applies from the next call.
//!
//! ## Nesting and panics
//!
//! A pool entry point invoked from *inside* a job body (nested
//! parallelism) runs serially on the calling worker — never a deadlock,
//! documented behavior pinned by `tests/test_pool.rs`. A panic in a job
//! body is caught at the band boundary, the band is counted as finished
//! (so the pool is never wedged or poisoned for the next call), and the
//! submitter re-raises: the caller's own panic payload if band 0 threw,
//! otherwise a summary panic counting the failed worker bands.
//!
//! ## Entry points (who partitions what)
//!
//! * [`parallel_for`] — contiguous index ranges, read-only sharing.
//! * [`parallel_chunks_mut`] — disjoint mutable chunks of one slice
//!   (column groups of a column-major panel): dense GEMMs, scatter SpMMᵀ.
//! * [`parallel_row_blocks`] — disjoint *row bands* of a column-major
//!   panel: the gather SpMM kernels, where threads own output rows.
//!   [`parallel_row_blocks_bounds`] is the caller-partitioned variant
//!   (explicit, possibly nnz-balanced, row bounds).
//! * [`parallel_reduce`] — map contiguous ranges to partials, fold in
//!   band (= index) order: the row-tiled SYRK and the CSR histograms.
//! * [`parallel_tasks`] — the low-level primitive under the others: run
//!   one prepared task per band (used by the CSR transpose fill, whose
//!   bands are nnz-balanced and therefore unevenly sized).
//!
//! All helpers are generic over the element type, so the f32 and f64
//! instantiations of the `Scalar` substrate share one threading layer.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

/// Hard cap on spawned workers (bands beyond it run on the submitter).
/// Far above any sane `TRUNKSVD_THREADS`; exists so a pathological
/// override cannot fork-bomb the process.
const MAX_WORKERS: usize = 256;

/// Runtime override for [`num_threads`] (0 = no override). Lets benches
/// and tests sweep thread counts inside one process, which the
/// env-var-derived default (cached in a `OnceLock`) cannot do.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Runtime override for [`parallel_cutoff`] (0 = no override).
static CUTOFF_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the worker-thread count for subsequent pool calls.
/// `set_num_threads(0)` clears the override (back to the env default).
///
/// Safe to call at any time from any thread that is not inside a pool
/// job: the pool resizes lazily on the next parallel call (see the
/// module docs for the grow/shrink semantics).
pub fn set_num_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Number of worker threads to use. Resolution order: the
/// [`set_num_threads`] override, then `TRUNKSVD_THREADS`, then
/// `available_parallelism`. The env lookup happens exactly once.
pub fn num_threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o != 0 {
        return o;
    }
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("TRUNKSVD_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Override the per-band element grain for subsequent pool calls
/// (`set_parallel_cutoff(0)` clears the override; `1` effectively forces
/// the parallel path, which the property tests use to exercise it on
/// small fixtures).
pub fn set_parallel_cutoff(n: usize) {
    CUTOFF_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Minimum number of owned elements per band before the slice-partitioned
/// helpers fan out. Resolution order: the [`set_parallel_cutoff`]
/// override, then `TRUNKSVD_PARALLEL_CUTOFF`, then the cost model's
/// [`crate::cost::parallel_cutoff`]. The env lookup happens exactly once.
pub fn parallel_cutoff() -> usize {
    let o = CUTOFF_OVERRIDE.load(Ordering::SeqCst);
    if o != 0 {
        return o;
    }
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("TRUNKSVD_PARALLEL_CUTOFF")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(crate::cost::parallel_cutoff)
    })
}

// ---------------------------------------------------------------------
// Worker pinning (TRUNKSVD_PIN): see the module docs for the model.
// ---------------------------------------------------------------------

/// Worker→CPU pinning policy (`TRUNKSVD_PIN`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PinLevel {
    /// No pinning (default): scheduler placement.
    Off,
    /// Pin each worker to one CPU, node-ordered round-robin.
    Core,
    /// Pin each worker to all CPUs of its assigned NUMA node.
    Node,
}

impl PinLevel {
    /// Name used in reports.
    pub fn name(self) -> &'static str {
        match self {
            PinLevel::Off => "off",
            PinLevel::Core => "core",
            PinLevel::Node => "node",
        }
    }

    /// Parse a `TRUNKSVD_PIN` value; unknown strings map to `None`
    /// (treated as `Off` by [`pin_level`]).
    pub fn parse(s: &str) -> Option<PinLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "none" => Some(PinLevel::Off),
            "core" => Some(PinLevel::Core),
            "node" => Some(PinLevel::Node),
            _ => None,
        }
    }
}

/// The pinning policy for this process (`TRUNKSVD_PIN`, default `off`;
/// resolved once — pinning happens at worker spawn, so a mid-process
/// change could not be honored anyway).
pub fn pin_level() -> PinLevel {
    static LEVEL: OnceLock<PinLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        std::env::var("TRUNKSVD_PIN")
            .ok()
            .and_then(|v| PinLevel::parse(&v))
            .unwrap_or(PinLevel::Off)
    })
}

/// Host CPU topology: per-NUMA-node CPU id lists plus the flattened,
/// node-ordered `(node, cpu)` sequence bands are dealt onto.
pub struct Topology {
    /// CPU ids per NUMA node, node-major (`nodes[n]` = node n's CPUs).
    pub nodes: Vec<Vec<usize>>,
    flat: Vec<(usize, usize)>,
}

impl Topology {
    /// Number of NUMA nodes (>= 1; non-NUMA hosts report one node).
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Parse a sysfs cpulist (`"0-3,8,10-11"`) into CPU ids. Malformed
/// fragments are skipped rather than failing the whole list.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                for c in a..=b.min(a + 4096) {
                    out.push(c);
                }
            }
        } else if let Ok(c) = part.parse::<usize>() {
            out.push(c);
        }
    }
    out
}

fn detect_topology() -> Topology {
    let mut nodes: Vec<Vec<usize>> = Vec::new();
    #[cfg(target_os = "linux")]
    for n in 0..MAX_WORKERS {
        match std::fs::read_to_string(format!("/sys/devices/system/node/node{n}/cpulist")) {
            Ok(s) => {
                let cpus = parse_cpulist(&s);
                // Memory-only nodes (no CPUs) exist; skip but keep going.
                if !cpus.is_empty() {
                    nodes.push(cpus);
                }
            }
            Err(_) => break,
        }
    }
    if nodes.is_empty() {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        nodes.push((0..n).collect());
    }
    let mut flat = Vec::new();
    for (ni, cpus) in nodes.iter().enumerate() {
        for &c in cpus {
            flat.push((ni, c));
        }
    }
    Topology { nodes, flat }
}

/// The host topology, detected once.
pub fn topology() -> &'static Topology {
    static TOPO: OnceLock<Topology> = OnceLock::new();
    TOPO.get_or_init(detect_topology)
}

#[cfg(target_os = "linux")]
mod affinity {
    // std already links libc on Linux, so a direct extern declaration
    // gives us the syscall without a new crate dependency.
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }

    /// Pin the calling thread to `cpus` (ids >= 1024 ignored). Returns
    /// false when the mask is empty or the kernel rejects it; failure
    /// leaves the thread unpinned, which is always safe.
    pub fn pin_to_cpus(cpus: &[usize]) -> bool {
        let mut mask = [0u64; 16]; // 1024-CPU mask
        let mut any = false;
        for &c in cpus {
            if c < 1024 {
                mask[c / 64] |= 1u64 << (c % 64);
                any = true;
            }
        }
        if !any {
            return false;
        }
        // SAFETY: pid 0 addresses the calling thread; the mask buffer
        // outlives the call and the length matches.
        unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
    }
}

#[cfg(not(target_os = "linux"))]
mod affinity {
    /// Pinning is Linux-only; everywhere else this is a no-op.
    pub fn pin_to_cpus(_cpus: &[usize]) -> bool {
        false
    }
}

/// Apply `TRUNKSVD_PIN` to worker `band` (1-based; band 0 is the
/// submitting thread, which belongs to the caller and is never pinned).
/// Bands map round-robin onto the flattened node-ordered CPU list, so
/// consecutive bands pack one NUMA node before spilling to the next.
fn pin_worker(band: usize) {
    let level = pin_level();
    if level == PinLevel::Off || band == 0 {
        return;
    }
    let topo = topology();
    if topo.flat.is_empty() {
        return;
    }
    let (node, cpu) = topo.flat[(band - 1) % topo.flat.len()];
    let _pinned = match level {
        PinLevel::Off => return,
        PinLevel::Core => affinity::pin_to_cpus(&[cpu]),
        PinLevel::Node => affinity::pin_to_cpus(&topo.nodes[node]),
    };
    // Failure (cgroup-restricted mask, exotic kernel) is harmless: the
    // worker runs unpinned exactly as under `off`.
}

thread_local! {
    /// True while this thread is executing a pool job band (worker or
    /// submitter). Nested entry-point calls check it and degrade to
    /// serial execution instead of deadlocking on the submit lock.
    static IN_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Is the current thread inside a pool job band? (Nested parallel calls
/// run serially — see the module docs.)
pub fn in_parallel_job() -> bool {
    IN_JOB.with(|c| c.get())
}

thread_local! {
    /// Per-thread cooperative-yield hook fired by the iterative solvers
    /// at outer-iteration boundaries (see [`restart_yield`]).
    static RESTART_YIELD_HOOK: std::cell::RefCell<Option<Box<dyn FnMut()>>> =
        const { std::cell::RefCell::new(None) };
}

/// Install (or clear, with `None`) the calling thread's
/// restart-boundary yield hook. The multi-tenant serving layer
/// (`runtime::serve`) installs one per solver thread so long jobs hand
/// the scheduler a chance between Lanczos restarts / power iterations
/// (fair FIFO-within-shape-class needs long solves to be preemptible at
/// their natural safepoints), and so reuse metrics can count boundaries.
/// Threads with no hook installed pay a thread-local read per restart
/// and nothing else.
pub fn set_restart_yield_hook(hook: Option<Box<dyn FnMut()>>) {
    RESTART_YIELD_HOOK.with(|h| *h.borrow_mut() = hook);
}

/// Cooperative scheduling point: the solvers call this at every outer
/// Lanczos-restart / power-iteration boundary (between restarts — never
/// inside the inner block recurrence). Purely a scheduling hook: it
/// performs no numeric work, so fixed-seed solves are bitwise identical
/// whether or not a hook is installed.
pub fn restart_yield() {
    RESTART_YIELD_HOOK.with(|h| {
        if let Some(f) = h.borrow_mut().as_mut() {
            f();
        }
    });
}

/// Current job, lifetime-erased. The submitter keeps the closure alive
/// on its stack until every band has finished, which is what makes the
/// erasure sound.
#[derive(Clone, Copy)]
struct JobRef(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared by all bands by design) and the
// broadcast protocol guarantees it outlives every use.
unsafe impl Send for JobRef {}

struct State {
    /// Stamp incremented per broadcast; workers detect new jobs by
    /// comparing against the last generation they observed.
    generation: u64,
    job: Option<JobRef>,
    /// Bands 0..participants run this generation (band 0 = submitter).
    participants: usize,
    /// Worker bands that have not yet finished the current generation.
    remaining: usize,
    /// Worker bands that panicked in the current generation.
    panics: usize,
    /// Workers spawned so far (live for the rest of the process).
    spawned: usize,
}

struct Pool {
    state: Mutex<State>,
    /// Wakes parked workers when a new generation is published.
    work_cv: Condvar,
    /// Wakes the submitter when `remaining` hits zero.
    done_cv: Condvar,
    /// Serializes broadcasts (one job in flight at a time).
    submit: Mutex<()>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // The pool is panic-safe by construction (no lock is held across job
    // bodies), so a poisoned mutex only means some unrelated thread
    // panicked while holding it; the data is still consistent.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn global() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        state: Mutex::new(State {
            generation: 0,
            job: None,
            participants: 0,
            remaining: 0,
            panics: 0,
            spawned: 0,
        }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
        submit: Mutex::new(()),
    })
}

/// Body of worker `band` (bands are 1-based; 0 is the submitter). `seen`
/// starts at the generation current when the worker was registered, so a
/// job published immediately after spawn is observed exactly once.
fn worker_loop(band: usize, mut seen: u64) {
    pin_worker(band);
    let pool = global();
    loop {
        let job = {
            let mut st = lock(&pool.state);
            loop {
                if st.generation != seen {
                    seen = st.generation;
                    if band < st.participants {
                        break st.job.expect("pool: generation advanced without a job");
                    }
                    // Not a participant this generation (pool shrunk);
                    // record the stamp and keep sleeping.
                }
                st = pool.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // SAFETY: the submitter blocks until `remaining` reaches zero,
        // which happens strictly after this call returns.
        let f = unsafe { &*job.0 };
        IN_JOB.with(|c| c.set(true));
        let result = catch_unwind(AssertUnwindSafe(|| f(band)));
        IN_JOB.with(|c| c.set(false));
        let mut st = lock(&pool.state);
        if result.is_err() {
            st.panics += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            pool.done_cv.notify_all();
        }
    }
}

/// Spawn workers until `target` are live (or spawning fails; the
/// submitter picks up any band that has no worker). Returns the live
/// worker count. Caller must hold the submit lock.
fn ensure_workers(pool: &'static Pool, target: usize) -> usize {
    let mut st = lock(&pool.state);
    while st.spawned < target {
        let band = st.spawned + 1;
        let seen = st.generation;
        let spawned = std::thread::Builder::new()
            .name(format!("trunksvd-pool-{band}"))
            .spawn(move || worker_loop(band, seen));
        match spawned {
            Ok(handle) => {
                // Detach: workers are parked between jobs and live until
                // process exit.
                drop(handle);
                st.spawned += 1;
            }
            Err(_) => break,
        }
    }
    st.spawned
}

/// Publish `f` as one generation over `bands` band indices and run it to
/// completion: workers take bands `1..=w`, the calling thread takes band
/// 0 plus any band beyond the spawnable worker count. Panics in any band
/// are re-raised here after *all* bands finish, so the pool state is
/// clean for the next call. Must not be called from inside a job.
fn broadcast(bands: usize, f: &(dyn Fn(usize) + Sync)) {
    debug_assert!(bands >= 2, "broadcast needs >= 2 bands");
    debug_assert!(!in_parallel_job(), "broadcast from inside a pool job");
    let pool = global();
    let guard = lock(&pool.submit);
    let workers = ensure_workers(pool, (bands - 1).min(MAX_WORKERS));
    let wbands = workers.min(bands - 1);
    // SAFETY: only the lifetime is erased; this function does not return
    // until every band has run, so the borrow cannot dangle.
    let job = JobRef(unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
    });
    {
        let mut st = lock(&pool.state);
        st.generation = st.generation.wrapping_add(1);
        st.job = Some(job);
        st.participants = wbands + 1;
        st.remaining = wbands;
        st.panics = 0;
        pool.work_cv.notify_all();
    }
    // Band 0 — and any band that could not get a worker — runs here.
    IN_JOB.with(|c| c.set(true));
    let own = catch_unwind(AssertUnwindSafe(|| {
        f(0);
        for b in (wbands + 1)..bands {
            f(b);
        }
    }));
    IN_JOB.with(|c| c.set(false));
    let worker_panics = {
        let mut st = lock(&pool.state);
        while st.remaining > 0 {
            st = pool.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        st.panics
    };
    drop(guard);
    if let Err(payload) = own {
        resume_unwind(payload);
    }
    if worker_panics > 0 {
        panic!("pool: {worker_panics} worker band(s) panicked in a parallel job");
    }
}

/// Band count for a coarse-task helper (`parallel_for`): every index may
/// hide arbitrary work, so no element cutoff applies.
fn plan_tasks(n: usize) -> usize {
    if in_parallel_job() {
        return 1;
    }
    let t = num_threads();
    if t <= 1 || n < 2 {
        return 1;
    }
    t.min(n).min(MAX_WORKERS + 1)
}

/// Band count a slice-partitioned helper would use for `work` elements
/// over `tasks` atomic units (1 = the serial fast path). Public so
/// kernels with a dedicated allocation-free serial variant (the SYRK's
/// direct-accumulation path in `blas3::gram_into`) can make the same
/// decision the pool would.
pub fn planned_bands(work: usize, tasks: usize) -> usize {
    plan_work(work, tasks)
}

/// Page-aligned first-touch row bounds: one band per configured worker
/// (at most one per `page_elems`-sized page run), strictly increasing
/// and spanning `[0, rows]`. Under `TRUNKSVD_PIN` the workspace arena
/// zero-fills through [`parallel_row_blocks_bounds`] with these bounds
/// so band `w`'s pages are faulted — and, on a first-touch NUMA host,
/// placed — by the same pinned worker that will stream them in the
/// banded kernels ([`parallel_row_blocks_work`] plans its bands from
/// the identical thread count, so the partitions coincide whenever the
/// work estimate saturates the pool).
pub fn first_touch_bounds(rows: usize, page_elems: usize) -> Vec<usize> {
    let page = page_elems.max(1);
    let pages = rows.div_ceil(page).max(1);
    let nb = num_threads().min(pages).max(1);
    let per = pages.div_ceil(nb);
    let mut bounds = Vec::with_capacity(nb + 1);
    bounds.push(0usize);
    for w in 0..nb {
        let hi = ((w + 1) * per * page).min(rows);
        if hi > *bounds.last().unwrap() {
            bounds.push(hi);
        }
    }
    debug_assert_eq!(*bounds.last().unwrap(), rows);
    bounds
}

/// Band count for a slice-partitioned helper owning `work` elements
/// split across at most `tasks` atomic units: scale bands so each owns
/// at least [`parallel_cutoff`] elements, capped by the thread count.
fn plan_work(work: usize, tasks: usize) -> usize {
    if in_parallel_job() {
        return 1;
    }
    let t = num_threads();
    if t <= 1 || tasks < 2 {
        return 1;
    }
    let grain = parallel_cutoff().max(1);
    t.min(tasks).min(work / grain).min(MAX_WORKERS + 1).max(1)
}

/// Run `body(task_index, task)` for every prepared task in parallel on
/// the persistent pool, each task exactly once. Tasks own their
/// (disjoint) data — typically pre-split `&mut` bands of an output
/// buffer — so `body` gets each by value. Tasks are dealt to at most
/// `num_threads()` bands in contiguous index batches (task `k` always
/// lands on the same band for a fixed `(len, num_threads)` — band
/// affinity). Serial fallbacks (single task, one configured thread, or a
/// nested call from inside a job) run the tasks in index order on the
/// calling thread.
pub fn parallel_tasks<T, F>(tasks: Vec<T>, body: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let n = tasks.len();
    let bands = plan_tasks(n);
    if bands <= 1 {
        for (k, task) in tasks.into_iter().enumerate() {
            body(k, task);
        }
        return;
    }
    let per = n.div_ceil(bands);
    let bands = n.div_ceil(per);
    let slots: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    broadcast(bands, &|w| {
        for k in (w * per)..((w + 1) * per).min(n) {
            let task = lock(&slots[k]).take().expect("pool: task dispatched twice");
            body(k, task);
        }
    });
}

/// Run `body(i)` for every `i in 0..n`, partitioned into contiguous
/// chunks across the worker bands. `body` must be `Sync` (no mutable
/// sharing); callers that need per-index output write to disjoint slices.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, body: F) {
    let bands = plan_tasks(n);
    if bands <= 1 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = n.div_ceil(bands);
    let bands = n.div_ceil(chunk); // drop empty trailing bands
    broadcast(bands, &|w| {
        let lo = w * chunk;
        let hi = ((w + 1) * chunk).min(n);
        for i in lo..hi {
            body(i);
        }
    });
}

/// Partition `data` into disjoint mutable chunks of `chunk_len` and run
/// `body(chunk_index, chunk)` in parallel. Used for column-panel updates
/// on column-major matrices. Chunks are dealt to bands in contiguous
/// batches, so chunk `c` always lands on the same band (and worker) for
/// a fixed `(len, num_threads, parallel_cutoff)` — the band-affinity
/// property. The work estimate defaults to `data.len()`; kernels whose
/// per-chunk cost is not proportional to the output size (e.g. the
/// scatter SpMMᵀ, which streams all of A per output column) pass a
/// truthful element count via [`parallel_chunks_mut_work`].
pub fn parallel_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    body: F,
) {
    let work = data.len();
    parallel_chunks_mut_work(data, chunk_len, work, body);
}

/// [`parallel_chunks_mut`] with an explicit `work` estimate (total
/// scalar elements the whole call will touch) for the serial-cutoff /
/// band-count decision.
pub fn parallel_chunks_mut_work<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    work: usize,
    body: F,
) {
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let bands = plan_work(work, n_chunks);
    if bands <= 1 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            body(ci, chunk);
        }
        return;
    }
    // Each band takes one contiguous batch of ceil(n_chunks / bands)
    // chunks.
    let per = n_chunks.div_ceil(bands);
    let mut tasks = Vec::with_capacity(bands);
    let mut rest = data;
    let mut ci = 0usize;
    while !rest.is_empty() {
        let take = (per * chunk_len).min(rest.len());
        let (head, tail) = rest.split_at_mut(take);
        let batch = head.len().div_ceil(chunk_len);
        tasks.push((ci, head));
        ci += batch;
        rest = tail;
    }
    parallel_tasks(tasks, |_w, (base, slice)| {
        for (k, chunk) in slice.chunks_mut(chunk_len).enumerate() {
            body(base + k, chunk);
        }
    });
}

/// Map-reduce over `0..n`: each band computes `map(lo, hi)` on one
/// contiguous sub-range, and the partials are folded with `reduce` in
/// band (= index) order starting from `identity`. With one band this is
/// exactly `reduce(identity, map(0, n))`, so a concatenating `reduce`
/// preserves element order — and because the partition and fold order
/// are static, the result is bitwise-reproducible for a fixed
/// `(n, num_threads, parallel_cutoff)`. The work estimate defaults to
/// `n`; reductions whose per-index cost hides more elements (the SYRK
/// reads b elements per row, the CSR row merge is nnz-proportional)
/// pass a truthful count via [`parallel_reduce_work`].
pub fn parallel_reduce<T, M, R>(n: usize, identity: T, map: M, reduce: R) -> T
where
    T: Send,
    M: Fn(usize, usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    parallel_reduce_work(n, n, identity, map, reduce)
}

/// [`parallel_reduce`] with an explicit `work` estimate (total scalar
/// elements the whole call will touch) for the serial-cutoff /
/// band-count decision.
pub fn parallel_reduce_work<T, M, R>(n: usize, work: usize, identity: T, map: M, reduce: R) -> T
where
    T: Send,
    M: Fn(usize, usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    let bands = plan_work(work, n);
    if bands <= 1 {
        if n == 0 {
            return identity;
        }
        return reduce(identity, map(0, n));
    }
    let chunk = n.div_ceil(bands);
    let bands = n.div_ceil(chunk);
    let slots: Vec<Mutex<Option<T>>> = (0..bands).map(|_| Mutex::new(None)).collect();
    broadcast(bands, &|w| {
        let lo = w * chunk;
        let hi = ((w + 1) * chunk).min(n);
        let part = map(lo, hi);
        *lock(&slots[w]) = Some(part);
    });
    slots.into_iter().fold(identity, |acc, slot| {
        let part = slot
            .into_inner()
            .unwrap_or_else(|e| e.into_inner())
            .expect("pool: reduce band produced no partial");
        reduce(acc, part)
    })
}

/// Parallel histogram over `0..n`: each band fills a private
/// `bins`-sized count vector for its contiguous sub-range via
/// `fill(lo, hi, counts)`, and the per-band vectors are summed
/// elementwise. Shared by the CSR row/column counting passes.
pub fn parallel_histogram<F>(n: usize, bins: usize, fill: F) -> Vec<usize>
where
    F: Fn(usize, usize, &mut [usize]) + Sync,
{
    parallel_reduce(
        n,
        vec![0usize; bins],
        |lo, hi| {
            let mut c = vec![0usize; bins];
            fill(lo, hi, &mut c);
            c
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += y;
            }
            a
        },
    )
}

/// Partition a column-major panel (`data.len()` divisible by `col_len`)
/// into contiguous row bands aligned to `align` rows, and run
/// `body(row_lo, row_hi, cols)` in parallel, where `cols[j]` is the
/// `[row_lo, row_hi)` sub-slice of column `j`. Each band owns its row
/// range across *all* columns — the natural decomposition for row-gather
/// kernels (SpMM) on column-major output — and a given row band lands on
/// the same worker every call (band affinity). The work estimate
/// defaults to `data.len()`; gather kernels whose row cost is dominated
/// by the operand stream (nnz, not output rows) pass a truthful count
/// via [`parallel_row_blocks_work`].
pub fn parallel_row_blocks<T, F>(data: &mut [T], col_len: usize, align: usize, body: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [&mut [T]]) + Sync,
{
    let work = data.len();
    parallel_row_blocks_work(data, col_len, align, work, body);
}

/// [`parallel_row_blocks`] with an explicit `work` estimate (total
/// scalar elements the whole call will touch) for the serial-cutoff /
/// band-count decision.
pub fn parallel_row_blocks_work<T, F>(
    data: &mut [T],
    col_len: usize,
    align: usize,
    work: usize,
    body: F,
) where
    T: Send,
    F: Fn(usize, usize, &mut [&mut [T]]) + Sync,
{
    assert!(col_len > 0, "parallel_row_blocks: empty columns");
    assert_eq!(data.len() % col_len, 0, "parallel_row_blocks: ragged panel");
    let n_cols = data.len() / col_len;
    let align = align.max(1);
    let n_blocks = col_len.div_ceil(align);
    let bands = plan_work(work, n_blocks);
    if bands <= 1 {
        // Serial fast path. The per-column slice table lives on the
        // stack for every width the pipeline emits — orth panels are
        // b ≤ 32, but the SpMM outputs are r-wide sketches and r is
        // bucketed at ≤ 256 throughout (CLI sweeps, artifact buckets,
        // default LancSvdOpts) — keeping this path allocation-free in
        // steady state (4 KiB of stack); wider panels fall back to a
        // heap table.
        const STACK_COLS: usize = 256;
        if n_cols <= STACK_COLS {
            // (`[const { MaybeUninit::uninit() }; N]` would be tidier but
            // needs Rust 1.79; the crate's MSRV is 1.75.)
            let mut store: [std::mem::MaybeUninit<&mut [T]>; STACK_COLS] =
                std::array::from_fn(|_| std::mem::MaybeUninit::uninit());
            for (i, c) in data.chunks_mut(col_len).enumerate() {
                store[i].write(c);
            }
            // SAFETY: the first n_cols entries were initialized just
            // above, and MaybeUninit<&mut [T]> has the layout of &mut [T].
            let cols: &mut [&mut [T]] = unsafe {
                std::slice::from_raw_parts_mut(store.as_mut_ptr() as *mut &mut [T], n_cols)
            };
            body(0, col_len, cols);
        } else {
            let mut cols: Vec<&mut [T]> = data.chunks_mut(col_len).collect();
            body(0, col_len, &mut cols);
        }
        return;
    }
    // Aligned row bounds per band: ceil(n_blocks / bands) blocks each.
    let per = n_blocks.div_ceil(bands);
    let mut bounds = Vec::with_capacity(bands + 1);
    bounds.push(0usize);
    for w in 0..bands {
        let hi = ((w + 1) * per * align).min(col_len);
        if hi > *bounds.last().unwrap() {
            bounds.push(hi);
        }
    }
    debug_assert_eq!(*bounds.last().unwrap(), col_len);
    let nw = bounds.len() - 1;
    // Split every column at the bounds and deal the bands out as tasks.
    let mut tasks = Vec::with_capacity(nw);
    for w in 0..nw {
        tasks.push((bounds[w], bounds[w + 1], Vec::with_capacity(n_cols)));
    }
    for col in data.chunks_mut(col_len) {
        let mut rest = col;
        for task in tasks.iter_mut() {
            let take = task.1 - task.0;
            let (head, tail) = rest.split_at_mut(take);
            task.2.push(head);
            rest = tail;
        }
    }
    parallel_tasks(tasks, |_w, (lo, hi, mut cols)| body(lo, hi, &mut cols));
}

/// [`parallel_row_blocks`] with caller-supplied row bounds: a strictly
/// increasing `0 = bounds[0] < … < bounds[last] = col_len` sequence,
/// one band per consecutive pair. This is the entry point for cached
/// (e.g. nnz-balanced) band plans — the caller has already decided the
/// partition, so no work estimate or alignment applies here; pass a
/// 2-entry bounds vector to force the serial path. Band `w` lands on
/// the same worker for a fixed partition (band affinity), and serial
/// fallbacks (single band, nested call, one configured thread) run the
/// bands in index order on the calling thread.
pub fn parallel_row_blocks_bounds<T, F>(data: &mut [T], col_len: usize, bounds: &[usize], body: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [&mut [T]]) + Sync,
{
    assert!(col_len > 0, "parallel_row_blocks_bounds: empty columns");
    assert_eq!(data.len() % col_len, 0, "parallel_row_blocks_bounds: ragged panel");
    assert!(
        bounds.len() >= 2 && bounds[0] == 0 && *bounds.last().unwrap() == col_len,
        "parallel_row_blocks_bounds: bounds must span [0, col_len]"
    );
    let n_cols = data.len() / col_len;
    let nw = bounds.len() - 1;
    if nw == 1 {
        // Defer to the aligned helper's allocation-free serial path
        // (work estimate 0 always plans one band).
        parallel_row_blocks_work(data, col_len, 1, 0, body);
        return;
    }
    let mut tasks = Vec::with_capacity(nw);
    for w in 0..nw {
        debug_assert!(bounds[w] < bounds[w + 1], "bounds must strictly increase");
        tasks.push((bounds[w], bounds[w + 1], Vec::with_capacity(n_cols)));
    }
    for col in data.chunks_mut(col_len) {
        let mut rest = col;
        for task in tasks.iter_mut() {
            let take = task.1 - task.0;
            let (head, tail) = rest.split_at_mut(take);
            task.2.push(head);
            rest = tail;
        }
    }
    parallel_tasks(tasks, |_w, (lo, hi, mut cols)| body(lo, hi, &mut cols));
}

/// PR 1's spawn-per-call dispatch (`std::thread::scope` on every call),
/// kept only as the baseline arm of the `pool_dispatch_ns` microbench in
/// `bench_blocks`. Not used by any kernel.
#[doc(hidden)]
pub fn parallel_for_spawn_baseline<F: Fn(usize) + Sync>(n: usize, body: F) {
    let t = num_threads().min(n.max(1));
    if t <= 1 || n < 2 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = n.div_ceil(t);
    std::thread::scope(|scope| {
        for w in 0..t {
            let body = &body;
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            scope.spawn(move || {
                for i in lo..hi {
                    body(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for(97, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_and_one() {
        parallel_for(0, |_| panic!("must not run"));
        let c = AtomicU64::new(0);
        parallel_for(1, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunks_mut_disjoint_and_complete() {
        let mut v = vec![0u64; 103];
        parallel_chunks_mut(&mut v, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x += 1 + ci as u64;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, 1 + (i / 10) as u64, "index {i}");
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn reduce_sums_and_preserves_order() {
        // Sum 0..=999 via per-range partial sums.
        let s = parallel_reduce(
            1000,
            0u64,
            |lo, hi| (lo as u64..hi as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(s, 499_500);
        // Concatenating reduce keeps index order.
        let v = parallel_reduce(
            257,
            Vec::new(),
            |lo, hi| (lo..hi).collect::<Vec<usize>>(),
            |mut a, mut b| {
                a.append(&mut b);
                a
            },
        );
        assert_eq!(v, (0..257).collect::<Vec<usize>>());
        // Empty range returns the identity untouched.
        assert_eq!(parallel_reduce(0, 41, |_, _| panic!("no work"), |a, b: i32| a + b), 41);
    }

    #[test]
    fn row_blocks_cover_panel() {
        // 103 rows x 5 cols, align 8: every element visited exactly once,
        // and the row/col coordinates reported to the body are correct.
        let (rows, cols_n) = (103usize, 5usize);
        let mut v = vec![0u64; rows * cols_n];
        parallel_row_blocks(&mut v, rows, 8, |lo, hi, cols| {
            assert_eq!(cols.len(), cols_n);
            for (j, col) in cols.iter_mut().enumerate() {
                assert_eq!(col.len(), hi - lo);
                for (o, x) in col.iter_mut().enumerate() {
                    *x += 1 + ((lo + o) * 10 + j) as u64;
                }
            }
        });
        for j in 0..cols_n {
            for i in 0..rows {
                assert_eq!(v[j * rows + i], 1 + (i * 10 + j) as u64, "({i},{j})");
            }
        }
    }

    #[test]
    fn histogram_counts_every_index_once() {
        let data: Vec<usize> = (0..1000).map(|i| i % 7).collect();
        let h = parallel_histogram(data.len(), 7, |lo, hi, c| {
            for &v in &data[lo..hi] {
                c[v] += 1;
            }
        });
        assert_eq!(h.iter().sum::<usize>(), 1000);
        assert_eq!(h[0], 143); // 0 appears for i in {0,7,...,994}
        assert_eq!(parallel_histogram(0, 3, |_, _, _| panic!("no work")), vec![0; 3]);
    }

    #[test]
    fn thread_override_round_trip() {
        let before = num_threads();
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0);
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn cutoff_override_round_trip() {
        let before = parallel_cutoff();
        assert!(before >= 1);
        set_parallel_cutoff(7);
        assert_eq!(parallel_cutoff(), 7);
        set_parallel_cutoff(0);
        assert_eq!(parallel_cutoff(), before);
    }

    #[test]
    fn parallel_tasks_each_task_once_in_band_order() {
        let tasks: Vec<usize> = (0..9).map(|i| i * 11).collect();
        let hits: Vec<AtomicU64> = (0..9).map(|_| AtomicU64::new(0)).collect();
        parallel_tasks(tasks, |w, task| {
            assert_eq!(task, w * 11, "task {w} routed to wrong band");
            hits[w].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // Empty task list is a no-op.
        parallel_tasks(Vec::<usize>::new(), |_, _| panic!("must not run"));
    }

    #[test]
    fn pin_level_parse() {
        assert_eq!(PinLevel::parse("off"), Some(PinLevel::Off));
        assert_eq!(PinLevel::parse(" CORE "), Some(PinLevel::Core));
        assert_eq!(PinLevel::parse("node"), Some(PinLevel::Node));
        assert_eq!(PinLevel::parse("aggressive"), None);
        assert_eq!(PinLevel::Node.name(), "node");
        // The process default must resolve without panicking.
        let _ = pin_level();
    }

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3,8,10-11\n"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("2-1,junk,7"), vec![7]); // bad fragments skipped
    }

    #[test]
    fn topology_covers_at_least_one_cpu() {
        let topo = topology();
        assert!(topo.num_nodes() >= 1);
        assert!(topo.nodes.iter().all(|n| !n.is_empty()));
        assert!(!topo.flat.is_empty());
        // Flat order is node-major: node indices are non-decreasing.
        assert!(topo.flat.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn row_blocks_bounds_cover_panel() {
        // Uneven explicit bounds: every element visited exactly once
        // with correct row coordinates, same contract as the aligned
        // helper.
        let (rows, cols_n) = (103usize, 4usize);
        for bounds in [vec![0usize, 103], vec![0, 7, 64, 103], vec![0, 1, 2, 3, 103]] {
            let mut v = vec![0u64; rows * cols_n];
            parallel_row_blocks_bounds(&mut v, rows, &bounds, |lo, hi, cols| {
                assert_eq!(cols.len(), cols_n);
                for (j, col) in cols.iter_mut().enumerate() {
                    assert_eq!(col.len(), hi - lo);
                    for (o, x) in col.iter_mut().enumerate() {
                        *x += 1 + ((lo + o) * 10 + j) as u64;
                    }
                }
            });
            for j in 0..cols_n {
                for i in 0..rows {
                    assert_eq!(v[j * rows + i], 1 + (i * 10 + j) as u64, "({i},{j}) {bounds:?}");
                }
            }
        }
    }

    #[test]
    fn first_touch_bounds_invariants() {
        for rows in [1usize, 7, 512, 513, 4096, 100_003] {
            for page in [1usize, 64, 512, 1024] {
                let b = first_touch_bounds(rows, page);
                assert!(b.len() >= 2, "rows={rows} page={page}: {b:?}");
                assert_eq!(b[0], 0);
                assert_eq!(*b.last().unwrap(), rows);
                assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
                // Interior bounds are page-aligned (only the final bound
                // may land mid-page, at `rows` itself).
                assert!(
                    b[1..b.len() - 1].iter().all(|&x| x % page == 0),
                    "rows={rows} page={page}: {b:?}"
                );
                // One band per worker at most.
                assert!(b.len() - 1 <= num_threads().max(1));
            }
        }
        // Fewer page runs than workers: never split below one page.
        let b = first_touch_bounds(10, 4096);
        assert_eq!(b, vec![0, 10]);
    }

    #[test]
    fn nested_calls_degrade_to_serial() {
        let total = AtomicU64::new(0);
        parallel_for(4, |_| {
            // Nested reduce must complete (serially) without deadlock.
            let s = parallel_reduce(
                100,
                0u64,
                |lo, hi| (lo as u64..hi as u64).sum::<u64>(),
                |a, b| a + b,
            );
            total.fetch_add(s, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 4950);
        assert!(!in_parallel_job(), "flag must clear after the job");
    }
}
