//! Tiny data-parallel helper.
//!
//! No rayon/tokio in the offline vendor set, so the hot loops use this
//! `parallel_for` built on `std::thread::scope`. On a single-core testbed
//! (the current image) it degrades to a serial loop with zero thread
//! overhead; on multi-core hosts it chunks the index range across
//! `TRUNKSVD_THREADS` (default: available_parallelism) workers.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cached).
pub fn num_threads() -> usize {
    static N: AtomicUsize = AtomicUsize::new(0);
    let cached = N.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("TRUNKSVD_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    N.store(n, Ordering::Relaxed);
    n
}

/// Run `body(i)` for every `i in 0..n`, partitioned into contiguous chunks
/// across the worker threads. `body` must be `Sync` (no mutable sharing);
/// callers that need per-index output write to disjoint slices.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, body: F) {
    let t = num_threads().min(n.max(1));
    if t <= 1 || n < 2 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = n.div_ceil(t);
    std::thread::scope(|scope| {
        for w in 0..t {
            let body = &body;
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            scope.spawn(move || {
                for i in lo..hi {
                    body(i);
                }
            });
        }
    });
}

/// Partition `data` into disjoint mutable chunks of `chunk_len` and run
/// `body(chunk_index, chunk)` in parallel. Used for column-panel updates
/// on column-major matrices.
pub fn parallel_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    body: F,
) {
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let t = num_threads().min(n_chunks.max(1));
    if t <= 1 || n_chunks < 2 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            body(ci, chunk);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut ci = 0;
        // Hand each worker an interleaved sequence is unnecessary; chunks
        // are roughly equal cost, so deal them out round-robin in batches.
        let per = n_chunks.div_ceil(t);
        for _ in 0..t {
            let take = (per * chunk_len).min(rest.len());
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let body = &body;
            let base = ci;
            ci += head.len().div_ceil(chunk_len);
            scope.spawn(move || {
                for (k, chunk) in head.chunks_mut(chunk_len).enumerate() {
                    body(base + k, chunk);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for(97, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_and_one() {
        parallel_for(0, |_| panic!("must not run"));
        let c = AtomicU64::new(0);
        parallel_for(1, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunks_mut_disjoint_and_complete() {
        let mut v = vec![0u64; 103];
        parallel_chunks_mut(&mut v, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x += 1 + ci as u64;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, 1 + (i / 10) as u64, "index {i}");
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
