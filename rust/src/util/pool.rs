//! Tiny data-parallel helpers.
//!
//! No rayon/tokio in the offline vendor set, so the hot loops use these
//! scoped-thread helpers built on `std::thread::scope`. On a single-core
//! testbed they degrade to serial loops with zero thread overhead; on
//! multi-core hosts they chunk work across `TRUNKSVD_THREADS` (default:
//! available parallelism) workers.
//!
//! Threading model (who partitions what):
//!
//! * [`parallel_for`] — contiguous index ranges, read-only sharing.
//! * [`parallel_chunks_mut`] — disjoint mutable chunks of one slice
//!   (column groups of a column-major panel). Used by the dense GEMMs
//!   and by the scatter SpMMᵀ, which partitions *output columns* so each
//!   thread owns whole columns of Y and the scatter stays race-free.
//! * [`parallel_row_blocks`] — disjoint *row bands* of a column-major
//!   panel: every worker gets the same row range of every column. Used
//!   by the gather SpMM kernels, where threads own output rows.
//! * [`parallel_reduce`] — map contiguous ranges to partials, fold them
//!   in worker order. Used by the row-tiled SYRK (Gram) reduction and
//!   the CSR histogram passes.
//!
//! All helpers are generic over the element type (`T: Send` /
//! `T` in the reduction), so the f32 and f64 instantiations of the
//! `Scalar` substrate share one threading layer unchanged.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Runtime override for [`num_threads`] (0 = no override). Lets benches
/// and tests sweep thread counts inside one process, which the
/// env-var-derived default (cached in a `OnceLock`) cannot do.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the worker-thread count for subsequent pool calls.
/// `set_num_threads(0)` clears the override (back to the env default).
pub fn set_num_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Number of worker threads to use. Resolution order: the
/// [`set_num_threads`] override, then `TRUNKSVD_THREADS`, then
/// `available_parallelism`. The env lookup happens exactly once.
pub fn num_threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o != 0 {
        return o;
    }
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("TRUNKSVD_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Run `body(i)` for every `i in 0..n`, partitioned into contiguous chunks
/// across the worker threads. `body` must be `Sync` (no mutable sharing);
/// callers that need per-index output write to disjoint slices.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, body: F) {
    let t = num_threads().min(n.max(1));
    if t <= 1 || n < 2 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = n.div_ceil(t);
    std::thread::scope(|scope| {
        for w in 0..t {
            let body = &body;
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            scope.spawn(move || {
                for i in lo..hi {
                    body(i);
                }
            });
        }
    });
}

/// Partition `data` into disjoint mutable chunks of `chunk_len` and run
/// `body(chunk_index, chunk)` in parallel. Used for column-panel updates
/// on column-major matrices.
pub fn parallel_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    body: F,
) {
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let t = num_threads().min(n_chunks.max(1));
    if t <= 1 || n_chunks < 2 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            body(ci, chunk);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut ci = 0;
        // Chunks are roughly equal cost, so each worker takes one
        // contiguous batch of ceil(n_chunks / t) chunks.
        let per = n_chunks.div_ceil(t);
        for _ in 0..t {
            let take = (per * chunk_len).min(rest.len());
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let body = &body;
            let base = ci;
            ci += head.len().div_ceil(chunk_len);
            scope.spawn(move || {
                for (k, chunk) in head.chunks_mut(chunk_len).enumerate() {
                    body(base + k, chunk);
                }
            });
        }
    });
}

/// Map-reduce over `0..n`: each worker computes `map(lo, hi)` on one
/// contiguous sub-range, and the partials are folded with `reduce` in
/// worker (= index) order starting from `identity`. With one worker this
/// is exactly `reduce(identity, map(0, n))`, so a concatenating `reduce`
/// preserves element order.
pub fn parallel_reduce<T, M, R>(n: usize, identity: T, map: M, reduce: R) -> T
where
    T: Send,
    M: Fn(usize, usize) -> T + Sync,
    R: Fn(T, T) -> T,
{
    let t = num_threads().min(n.max(1));
    if t <= 1 || n < 2 {
        if n == 0 {
            return identity;
        }
        return reduce(identity, map(0, n));
    }
    let chunk = n.div_ceil(t);
    let mut parts: Vec<T> = Vec::with_capacity(t);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(t);
        for w in 0..t {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            let map = &map;
            handles.push(scope.spawn(move || map(lo, hi)));
        }
        for h in handles {
            parts.push(h.join().expect("parallel_reduce worker panicked"));
        }
    });
    parts.into_iter().fold(identity, reduce)
}

/// Parallel histogram over `0..n`: each worker fills a private
/// `bins`-sized count vector for its contiguous sub-range via
/// `fill(lo, hi, counts)`, and the per-worker vectors are summed
/// elementwise. Shared by the CSR row/column counting passes.
pub fn parallel_histogram<F>(n: usize, bins: usize, fill: F) -> Vec<usize>
where
    F: Fn(usize, usize, &mut [usize]) + Sync,
{
    parallel_reduce(
        n,
        vec![0usize; bins],
        |lo, hi| {
            let mut c = vec![0usize; bins];
            fill(lo, hi, &mut c);
            c
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += y;
            }
            a
        },
    )
}

/// Partition a column-major panel (`data.len()` divisible by `col_len`)
/// into contiguous row bands aligned to `align` rows, and run
/// `body(row_lo, row_hi, cols)` in parallel, where `cols[j]` is the
/// `[row_lo, row_hi)` sub-slice of column `j`. Each worker owns its row
/// band across *all* columns, which is the natural decomposition for
/// row-gather kernels (SpMM) on column-major output.
pub fn parallel_row_blocks<T, F>(data: &mut [T], col_len: usize, align: usize, body: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [&mut [T]]) + Sync,
{
    assert!(col_len > 0, "parallel_row_blocks: empty columns");
    assert_eq!(data.len() % col_len, 0, "parallel_row_blocks: ragged panel");
    let n_cols = data.len() / col_len;
    let align = align.max(1);
    let n_blocks = col_len.div_ceil(align);
    let t = num_threads().min(n_blocks.max(1));
    if t <= 1 {
        let mut cols: Vec<&mut [T]> = data.chunks_mut(col_len).collect();
        body(0, col_len, &mut cols);
        return;
    }
    // Aligned row bounds per worker: ceil(n_blocks / t) blocks each.
    let per = n_blocks.div_ceil(t);
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    for w in 0..t {
        let hi = ((w + 1) * per * align).min(col_len);
        if hi > *bounds.last().unwrap() {
            bounds.push(hi);
        }
    }
    debug_assert_eq!(*bounds.last().unwrap(), col_len);
    let nw = bounds.len() - 1;
    // Split every column at the bounds and deal the bands to workers.
    let mut bands: Vec<Vec<&mut [T]>> = (0..nw).map(|_| Vec::with_capacity(n_cols)).collect();
    for col in data.chunks_mut(col_len) {
        let mut rest = col;
        for (w, band) in bands.iter_mut().enumerate() {
            let take = bounds[w + 1] - bounds[w];
            let (head, tail) = rest.split_at_mut(take);
            band.push(head);
            rest = tail;
        }
    }
    std::thread::scope(|scope| {
        for (w, mut cols) in bands.into_iter().enumerate() {
            let body = &body;
            let (lo, hi) = (bounds[w], bounds[w + 1]);
            scope.spawn(move || body(lo, hi, &mut cols));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        parallel_for(97, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_and_one() {
        parallel_for(0, |_| panic!("must not run"));
        let c = AtomicU64::new(0);
        parallel_for(1, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunks_mut_disjoint_and_complete() {
        let mut v = vec![0u64; 103];
        parallel_chunks_mut(&mut v, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x += 1 + ci as u64;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, 1 + (i / 10) as u64, "index {i}");
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn reduce_sums_and_preserves_order() {
        // Sum 0..=999 via per-range partial sums.
        let s = parallel_reduce(
            1000,
            0u64,
            |lo, hi| (lo as u64..hi as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(s, 499_500);
        // Concatenating reduce keeps index order.
        let v = parallel_reduce(
            257,
            Vec::new(),
            |lo, hi| (lo..hi).collect::<Vec<usize>>(),
            |mut a, mut b| {
                a.append(&mut b);
                a
            },
        );
        assert_eq!(v, (0..257).collect::<Vec<usize>>());
        // Empty range returns the identity untouched.
        assert_eq!(parallel_reduce(0, 41, |_, _| panic!("no work"), |a, b: i32| a + b), 41);
    }

    #[test]
    fn row_blocks_cover_panel() {
        // 103 rows x 5 cols, align 8: every element visited exactly once,
        // and the row/col coordinates reported to the body are correct.
        let (rows, cols_n) = (103usize, 5usize);
        let mut v = vec![0u64; rows * cols_n];
        parallel_row_blocks(&mut v, rows, 8, |lo, hi, cols| {
            assert_eq!(cols.len(), cols_n);
            for (j, col) in cols.iter_mut().enumerate() {
                assert_eq!(col.len(), hi - lo);
                for (o, x) in col.iter_mut().enumerate() {
                    *x += 1 + ((lo + o) * 10 + j) as u64;
                }
            }
        });
        for j in 0..cols_n {
            for i in 0..rows {
                assert_eq!(v[j * rows + i], 1 + (i * 10 + j) as u64, "({i},{j})");
            }
        }
    }

    #[test]
    fn histogram_counts_every_index_once() {
        let data: Vec<usize> = (0..1000).map(|i| i % 7).collect();
        let h = parallel_histogram(data.len(), 7, |lo, hi, c| {
            for &v in &data[lo..hi] {
                c[v] += 1;
            }
        });
        assert_eq!(h.iter().sum::<usize>(), 1000);
        assert_eq!(h[0], 143); // 0 appears for i in {0,7,...,994}
        assert_eq!(parallel_histogram(0, 3, |_, _, _| panic!("no work")), vec![0; 3]);
    }

    #[test]
    fn thread_override_round_trip() {
        let before = num_threads();
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0);
        assert_eq!(num_threads(), before);
    }
}
