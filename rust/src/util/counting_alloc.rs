//! Allocation-counting global allocator (test/bench instrumentation).
//!
//! The allocation-free-steady-state contract of the workspace refactor
//! is *pinned*, not assumed: `tests/test_workspace.rs` and the
//! `alloc_probe` section of `bench_blocks` install [`CountingAllocator`]
//! as their binary's `#[global_allocator]` and assert that the inner
//! iterations of both algorithms perform zero heap allocations on the
//! CPU backend. The library itself never installs it — the type lives
//! here so the test and bench binaries (which are separate crates)
//! share one implementation.
//!
//! Counters are kept **per thread** (`const`-initialized TLS `Cell`s, so
//! the counting path itself never allocates and never recurses) plus a
//! process-wide total. Thread-local counting is what makes the
//! steady-state assertions robust inside a multi-threaded test harness:
//! the measured ops run on the asserting thread (the serial fast path of
//! the pool at `TRUNKSVD_THREADS=1`), so allocations from unrelated
//! concurrent tests cannot pollute the window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static GLOBAL_COUNT: AtomicU64 = AtomicU64::new(0);
static GLOBAL_BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TL_COUNT: Cell<u64> = const { Cell::new(0) };
    static TL_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Allocations performed by the *current thread* since it started.
/// Measure a window by differencing two reads.
pub fn thread_allocs() -> u64 {
    TL_COUNT.with(|c| c.get())
}

/// Bytes allocated by the current thread since it started.
pub fn thread_alloc_bytes() -> u64 {
    TL_BYTES.with(|c| c.get())
}

/// Process-wide allocation count (all threads).
pub fn total_allocs() -> u64 {
    GLOBAL_COUNT.load(Ordering::Relaxed)
}

/// Process-wide allocated bytes (all threads).
pub fn total_alloc_bytes() -> u64 {
    GLOBAL_BYTES.load(Ordering::Relaxed)
}

#[inline]
fn count(bytes: usize) {
    GLOBAL_COUNT.fetch_add(1, Ordering::Relaxed);
    GLOBAL_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    TL_COUNT.with(|c| c.set(c.get() + 1));
    TL_BYTES.with(|c| c.set(c.get() + bytes as u64));
}

/// System-allocator wrapper that counts every allocation (including
/// grow-reallocs) per thread and process-wide. Install in a test/bench
/// binary with:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: trunksvd::util::counting_alloc::CountingAllocator =
///     trunksvd::util::counting_alloc::CountingAllocator;
/// ```
pub struct CountingAllocator;

// SAFETY: defers entirely to `System` for memory management; the
// counter updates are atomic / thread-local and never allocate.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A shrink is free in this accounting; a grow is one allocation.
        if new_size > layout.size() {
            count(new_size);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[cfg(test)]
mod tests {
    // The library's unit-test binary does not install the allocator, so
    // the counters just read zero here; the real coverage lives in
    // tests/test_workspace.rs which does install it.
    use super::*;

    #[test]
    fn counters_are_readable() {
        let c = thread_allocs();
        let b = thread_alloc_bytes();
        let _v: Vec<u8> = Vec::with_capacity(128);
        assert!(thread_allocs() >= c);
        assert!(thread_alloc_bytes() >= b);
        assert!(total_allocs() >= c);
        let _ = total_alloc_bytes();
    }
}
