//! Deterministic PRNG substrate.
//!
//! The paper initializes both methods with cuRAND-generated random vectors
//! ("Poisson distribution with zero mean and deviation of 1" — i.e. a
//! centered unit-variance draw; we provide centered Poisson(1) as well as
//! standard normal). No `rand` crate is available offline, so this module
//! implements splitmix64 (seeding) + xoshiro256++ (stream) from the public
//! reference algorithms, plus Box–Muller normal and Knuth Poisson samplers.

/// splitmix64 step — used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator. Deterministic, seedable, fast, and good enough
/// statistically for test-matrix generation (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-column / per-worker use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output (xoshiro256++ scrambler).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) by rejection (unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        // Lemire-style: take high multiply; rejection on the low part.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n || n.is_power_of_two() {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Poisson(lambda) via Knuth's product method (lambda is small here).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda > 0.0 && lambda < 30.0);
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// The paper's initial-vector distribution: a centered, unit-variance
    /// Poisson draw (Poisson(1) − 1).
    #[inline]
    pub fn centered_poisson(&mut self) -> f64 {
        self.poisson(1.0) as f64 - 1.0
    }

    /// Fill a slice with standard normals.
    ///
    /// Generic over the element type: every draw happens on the shared
    /// f64 Box–Muller stream and is *rounded* to `S`, so the f32 and f64
    /// fills from the same seed consume identical generator state and
    /// agree elementwise to f32 precision (the cross-dtype parity tests
    /// rely on this determinism).
    pub fn fill_normal<S: crate::util::scalar::Scalar>(&mut self, out: &mut [S]) {
        for v in out.iter_mut() {
            *v = S::from_f64(self.normal());
        }
    }

    /// Fill a slice with centered Poisson draws (paper's init). Same
    /// round-from-f64 contract as [`Rng::fill_normal`].
    pub fn fill_centered_poisson<S: crate::util::scalar::Scalar>(&mut self, out: &mut [S]) {
        for v in out.iter_mut() {
            *v = S::from_f64(self.centered_poisson());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn poisson_mean_var() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.centered_poisson();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.03, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var {m2}");
    }

    #[test]
    fn f32_and_f64_fills_agree_from_one_seed() {
        // Same seed ⇒ same underlying f64 stream; the f32 fill is that
        // stream rounded, so the two agree to f32 precision elementwise
        // and the generators stay in lock-step afterwards.
        let mut r64 = Rng::new(2024);
        let mut r32 = Rng::new(2024);
        let mut a = vec![0.0f64; 512];
        let mut b = vec![0.0f32; 512];
        r64.fill_normal(&mut a);
        r32.fill_normal(&mut b);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(*y, *x as f32, "element {i}: {x} vs {y}");
            assert!((x - *y as f64).abs() <= f32::EPSILON as f64 * x.abs().max(1.0));
        }
        // Generator state advanced identically.
        assert_eq!(r64.next_u64(), r32.next_u64());
        // Centered-Poisson fills share the contract.
        let mut p64 = Rng::new(7);
        let mut p32 = Rng::new(7);
        let mut c = vec![0.0f64; 128];
        let mut d = vec![0.0f32; 128];
        p64.fill_centered_poisson(&mut c);
        p32.fill_centered_poisson(&mut d);
        for (x, y) in c.iter().zip(&d) {
            assert_eq!(*y, *x as f32);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert!(same < 2);
    }
}
