//! The `Scalar` value-type abstraction (f32 / f64).
//!
//! The paper's GPU experiments run in *single precision*: its headline
//! kernels (SpMM, SYRK/Gram, CholeskyQR2) are memory-bandwidth-bound, so
//! halving the element width roughly doubles effective bandwidth on every
//! hot loop. This trait is the substrate that lets the whole numeric
//! stack — `la::mat::Mat<S>`, the BLAS-1/3 kernels, the sparse formats,
//! and the `algo` drivers — run end-to-end in either precision, with
//! `f64` kept as the default type parameter everywhere so existing
//! f64-only call sites compile unchanged.
//!
//! Design rules:
//!
//! * All *metrics and reports* (residuals, timings, JSON) stay `f64`;
//!   `Scalar::to_f64` is the single conversion point.
//! * Random fills draw from the shared f64 generator stream and round to
//!   `S` (see [`crate::util::rng::Rng::fill_normal`]), so the f32 and f64
//!   streams from one seed agree to f32 precision — the property the
//!   cross-dtype parity tests pin down.
//! * Tolerances in generic code scale with `S::EPSILON`, never hard-coded
//!   f64 magnitudes.

use crate::util::json::Json;

/// Floating-point element type for the numeric substrate (f32 or f64).
pub trait Scalar:
    Copy
    + Clone
    + Send
    + Sync
    + 'static
    + std::fmt::Debug
    + std::fmt::Display
    + PartialEq
    + PartialOrd
    + Default
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + std::ops::DivAssign
    + std::iter::Sum<Self>
{
    const ZERO: Self;
    const ONE: Self;
    /// Machine epsilon of the type (2⁻⁵² / 2⁻²³).
    const EPSILON: Self;
    /// dtype tag used in reports and `BENCH_kernels.json` ("f32"/"f64").
    const DTYPE: &'static str;

    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn mul_add(self, a: Self, b: Self) -> Self;
    fn max(self, other: Self) -> Self;
    fn is_finite(self) -> bool;

    /// |x| range whose square stays comfortably inside the dynamic range
    /// (used by the scaled `nrm2`): (lo, hi) with lo² above underflow and
    /// hi² below overflow even after length-n accumulation.
    fn safe_sq_range() -> (Self, Self);

    /// JSON emission for reports (numbers are f64 on the wire).
    fn to_json(self) -> Json {
        Json::Num(self.to_f64())
    }

    // SIMD microkernel dispatch (see [`crate::util::simd`]). These are
    // the only vocabulary the hot kernels use; every implementation —
    // scalar reference, AVX2, NEON — computes bitwise-identical results,
    // so callers may treat the `TRUNKSVD_SIMD` level as a pure speed
    // knob. The gathered forms take `u32` column indices (CSR layout);
    // every index must be in-bounds for the right-hand slices.

    /// `Σ x[i]·y[i]` in the canonical lane-blocked order.
    fn simd_dot(x: &[Self], y: &[Self]) -> Self;
    /// Two dots sharing the right-hand side: `(x0·y, x1·y)`.
    fn simd_dot2(x0: &[Self], x1: &[Self], y: &[Self]) -> (Self, Self);
    /// Four dots sharing the left-hand side: `(w·x0, …, w·x3)`.
    #[allow(clippy::type_complexity)]
    fn simd_dot4(
        w: &[Self],
        x0: &[Self],
        x1: &[Self],
        x2: &[Self],
        x3: &[Self],
    ) -> (Self, Self, Self, Self);
    /// `Σ vals[p]·x[idx[p]]` (one CSR row × one dense column).
    fn simd_gather_dot1(vals: &[Self], idx: &[u32], x: &[Self]) -> Self;
    /// Gathered dot over two dense columns.
    fn simd_gather_dot2(vals: &[Self], idx: &[u32], x0: &[Self], x1: &[Self]) -> (Self, Self);
    /// Gathered dot over four dense columns (the SpMM register block).
    #[allow(clippy::type_complexity)]
    fn simd_gather_dot4(
        vals: &[Self],
        idx: &[u32],
        x0: &[Self],
        x1: &[Self],
        x2: &[Self],
        x3: &[Self],
    ) -> (Self, Self, Self, Self);
    /// `y += a·x` (elementwise, no FMA).
    fn simd_axpy(a: Self, x: &[Self], y: &mut [Self]);
    /// `x *= a` (elementwise).
    fn simd_scal(a: Self, x: &mut [Self]);
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f64::EPSILON;
    const DTYPE: &'static str = "f64";

    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f64::mul_add(self, a, b)
    }
    #[inline]
    fn max(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f64::is_finite(self)
    }
    #[inline]
    fn safe_sq_range() -> (Self, Self) {
        (1e-140, 1e140)
    }

    #[inline]
    fn simd_dot(x: &[Self], y: &[Self]) -> Self {
        crate::util::simd::dot_f64(x, y)
    }
    #[inline]
    fn simd_dot2(x0: &[Self], x1: &[Self], y: &[Self]) -> (Self, Self) {
        crate::util::simd::dot2_f64(x0, x1, y)
    }
    #[inline]
    fn simd_dot4(
        w: &[Self],
        x0: &[Self],
        x1: &[Self],
        x2: &[Self],
        x3: &[Self],
    ) -> (Self, Self, Self, Self) {
        crate::util::simd::dot4_f64(w, x0, x1, x2, x3)
    }
    #[inline]
    fn simd_gather_dot1(vals: &[Self], idx: &[u32], x: &[Self]) -> Self {
        crate::util::simd::gather_dot1_f64(vals, idx, x)
    }
    #[inline]
    fn simd_gather_dot2(vals: &[Self], idx: &[u32], x0: &[Self], x1: &[Self]) -> (Self, Self) {
        crate::util::simd::gather_dot2_f64(vals, idx, x0, x1)
    }
    #[inline]
    fn simd_gather_dot4(
        vals: &[Self],
        idx: &[u32],
        x0: &[Self],
        x1: &[Self],
        x2: &[Self],
        x3: &[Self],
    ) -> (Self, Self, Self, Self) {
        crate::util::simd::gather_dot4_f64(vals, idx, x0, x1, x2, x3)
    }
    #[inline]
    fn simd_axpy(a: Self, x: &[Self], y: &mut [Self]) {
        crate::util::simd::axpy_f64(a, x, y)
    }
    #[inline]
    fn simd_scal(a: Self, x: &mut [Self]) {
        crate::util::simd::scal_f64(a, x)
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPSILON: Self = f32::EPSILON;
    const DTYPE: &'static str = "f32";

    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    #[inline]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f32::mul_add(self, a, b)
    }
    #[inline]
    fn max(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline]
    fn is_finite(self) -> bool {
        f32::is_finite(self)
    }
    #[inline]
    fn safe_sq_range() -> (Self, Self) {
        (1e-15, 1e15)
    }

    #[inline]
    fn simd_dot(x: &[Self], y: &[Self]) -> Self {
        crate::util::simd::dot_f32(x, y)
    }
    #[inline]
    fn simd_dot2(x0: &[Self], x1: &[Self], y: &[Self]) -> (Self, Self) {
        crate::util::simd::dot2_f32(x0, x1, y)
    }
    #[inline]
    fn simd_dot4(
        w: &[Self],
        x0: &[Self],
        x1: &[Self],
        x2: &[Self],
        x3: &[Self],
    ) -> (Self, Self, Self, Self) {
        crate::util::simd::dot4_f32(w, x0, x1, x2, x3)
    }
    #[inline]
    fn simd_gather_dot1(vals: &[Self], idx: &[u32], x: &[Self]) -> Self {
        crate::util::simd::gather_dot1_f32(vals, idx, x)
    }
    #[inline]
    fn simd_gather_dot2(vals: &[Self], idx: &[u32], x0: &[Self], x1: &[Self]) -> (Self, Self) {
        crate::util::simd::gather_dot2_f32(vals, idx, x0, x1)
    }
    #[inline]
    fn simd_gather_dot4(
        vals: &[Self],
        idx: &[u32],
        x0: &[Self],
        x1: &[Self],
        x2: &[Self],
        x3: &[Self],
    ) -> (Self, Self, Self, Self) {
        crate::util::simd::gather_dot4_f32(vals, idx, x0, x1, x2, x3)
    }
    #[inline]
    fn simd_axpy(a: Self, x: &[Self], y: &mut [Self]) {
        crate::util::simd::axpy_f32(a, x, y)
    }
    #[inline]
    fn simd_scal(a: Self, x: &mut [Self]) {
        crate::util::simd::scal_f32(a, x)
    }
}

/// Runtime precision choice, plumbed from the CLI / `config/suite.json`
/// down to the solve driver (`coordinator::driver`). `Hash` because the
/// serving layer (`runtime::serve`) keys its workspace pool and operand
/// cache on shape classes that include the dtype.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    #[default]
    F64,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    /// Parse "f32"/"f64" (also accepts "single"/"double").
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" | "single" | "fp32" => Some(DType::F32),
            "f64" | "double" | "fp64" => Some(DType::F64),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consts_and_conversions() {
        assert_eq!(<f64 as Scalar>::DTYPE, "f64");
        assert_eq!(<f32 as Scalar>::DTYPE, "f32");
        assert_eq!(f32::from_f64(1.5), 1.5f32);
        assert_eq!(Scalar::to_f64(2.5f32), 2.5f64);
        assert!(<f32 as Scalar>::EPSILON.to_f64() > <f64 as Scalar>::EPSILON);
    }

    #[test]
    fn ops_through_the_trait() {
        fn hypot<S: Scalar>(a: S, b: S) -> S {
            (a * a + b * b).sqrt()
        }
        assert!((hypot(3.0f32, 4.0f32) - 5.0).abs() < 1e-6);
        assert!((hypot(3.0f64, 4.0f64) - 5.0).abs() < 1e-12);
        fn fma<S: Scalar>(a: S, b: S, c: S) -> S {
            a.mul_add(b, c)
        }
        assert_eq!(fma(2.0f64, 3.0, 1.0), 7.0);
    }

    #[test]
    fn dtype_parse_and_name() {
        assert_eq!(DType::parse("f32"), Some(DType::F32));
        assert_eq!(DType::parse("double"), Some(DType::F64));
        assert_eq!(DType::parse("bf16"), None);
        assert_eq!(DType::default().name(), "f64");
    }

    #[test]
    fn json_emission() {
        assert_eq!(Scalar::to_json(1.5f32), Json::Num(1.5));
        assert_eq!(Scalar::to_json(-3.0f64), Json::Num(-3.0));
    }
}
