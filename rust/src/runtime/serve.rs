//! Multi-tenant truncated-SVD serving: many concurrent jobs over one
//! warm process.
//!
//! The one-shot CLI pays the full cold-start price on every query:
//! operand staging (CSR admission, explicit-transpose build, shard
//! manifest resolution), first-touch workspace arenas, and thread-pool
//! spin-up. A retrieval or LSI service issuing thousands of truncated
//! SVDs against a handful of corpus matrices re-pays those costs for no
//! reason. `trunksvd serve` keeps the process warm and multiplexes jobs
//! over three reuse layers:
//!
//! * **Workspace pool** ([`WorkspacePool`]) — solve arenas keyed by
//!   *shape class* `(kind, m, n, r, p, b, dtype)` ([`ShapeClass`]). A
//!   completed job checks its workspace back in; the next job of the
//!   same class reuses the warm, already-first-touched arena through
//!   the allocation-free [`lancsvd_with`] / [`randsvd_with`] entry
//!   points instead of paying `Workspace::new`.
//! * **Operand cache** ([`OperandCache`] inside the server) — built
//!   backends keyed by *operand identity*: the process-unique
//!   [`Csr::generation`](crate::sparse::csr::Csr::generation) stamp for
//!   in-core sparse, the shard-dir path + resident cap for out-of-core,
//!   or the caller-supplied [`JobSpec::operand_tag`] (the protocol layer
//!   uses the canonical operand-spec JSON). A repeat query against the
//!   same matrix skips staging entirely — including the eager explicit
//!   transpose — and lands on the warm backend.
//! * **Admission control** — a bounded queue ([`ServeConfig::queue_cap`])
//!   with per-job deadlines. A full queue or an expired deadline is a
//!   *typed rejection* ([`JobStatus::Rejected`]), distinct from a solve
//!   failure, so callers can tell backpressure from broken inputs.
//!
//! # Scheduling policy
//!
//! Jobs are FIFO *within* a shape class and round-robin *across*
//! classes: the scheduler keeps one sub-queue per class and rotates
//! through the non-empty classes, so a burst of large jobs cannot
//! starve a co-tenant's small ones, while same-class jobs retain
//! submission order (which maximizes warm-workspace and warm-backend
//! locality). Solver workers additionally install a cooperative
//! restart-boundary yield hook
//! ([`pool::set_restart_yield_hook`](crate::util::pool::set_restart_yield_hook)):
//! the algorithms call back at every outer-iteration boundary, giving
//! the OS a chance to interleave co-tenant solver threads at points
//! that have **no numeric effect**.
//!
//! # Determinism
//!
//! Repeat submissions of an identical job at a fixed
//! `TRUNKSVD_THREADS` return **bitwise-identical** singular values
//! regardless of interleaving with other tenants. Everything
//! schedule-dependent is kept out of the solve: backends are built by
//! [`make_send_backend_at`], whose `cpu` choice uses the *eager*
//! explicit transpose (the interactive adaptive transpose adopts its
//! cached copy at a schedule-dependent instant, which would flip
//! reduction orders between runs), and workspace reuse is
//! content-independent (arenas carry no state between solves that the
//! algorithms read before writing).
//!
//! # Job protocol
//!
//! One JSON object per line on stdin (or a unix socket via
//! `trunksvd serve --socket`), one JSON result object per line out
//! (order follows completion, not submission; match on `id`):
//!
//! ```text
//! {"id": "q1", "algo": "lanc", "r": 16, "p": 2, "b": 8, "seed": 7,
//!  "wanted": 4, "dtype": "f64",
//!  "operand": {"sparse": {"rows": 400, "cols": 160, "nnz": 6000, "seed": 11}}}
//! ```
//!
//! Operand specs: `{"suite": NAME}` (config/suite.json entry),
//! `{"mtx": PATH}`, `{"sparse": {rows, cols, nnz, seed[, skew,
//! value_decay]}}` (the synthetic generator), `{"dense": {m, n[,
//! seed]}}` (the paper's dense spectrum), `{"shards": DIR[,
//! "resident_cap": BYTES]}` (out-of-core). Identical operand specs
//! resolve to the *same* in-memory operand (one build, shared `Arc`),
//! which is what makes the operand cache hit across jobs. Optional
//! per-job fields: `deadline_ms` (0 ⇒ reject at admission —
//! deterministic, used by CI gates), `tol`, `restart`/`keep`, and the
//! fault-injection knobs `inject_panic` / `inject_delay_ms` (tests).
//!
//! Results: `{"id", "status": "ok"|"failed"|"rejected", "sigma": [..],
//! "iters", "secs", "queue_secs", "shape_class", "cols_seen",
//! "operand_hit", "workspace_warm"[, "error", "est_residuals"]}`.
//!
//! # Streaming tenants
//!
//! A job may carry `"kind": "append"|"query"|"finalize"` plus a
//! `"stream": NAME` to address a *streaming tenant*: a warm
//! [`IncrementalSvd`] basis (U, σ, V, cols_seen) living in the operand
//! cache under the key `stream:NAME|dtype|backend`.
//!
//! * `append` (+ `"cols": C`) absorbs the next C columns of the job's
//!   operand — the stream source — into the basis, in `b`-column
//!   blocks through the allocation-free
//!   [`IncrementalSvd::update_with`] path and a pooled
//!   [`Plan::incremental`] workspace. The result's `sigma` is the
//!   post-append spectrum snapshot and `cols_seen` the new stream
//!   length.
//! * `query` reads the warm basis's leading singular values without
//!   touching the operand or checking out a workspace (zero staging,
//!   zero crossings — see the backend contract §9).
//! * `finalize` returns the final spectrum, then retires the tenant:
//!   basis and backend are dropped and the slot forgets it ever built
//!   (so a repeated workload starts from a clean miss, not rework).
//!
//! Stream jobs schedule under the `inc` shape class, so same-stream
//! jobs are FIFO in submission order; a panic mid-append discards the
//! torn basis (the next append rebuilds from scratch, counted as
//! rework) — exactly the solve-path containment story.
//!
//! # Replay
//!
//! `trunksvd serve --replay config/workloads/smoke.json` replays a
//! committed workload (optionally several times over one warm server),
//! checks that repeat runs are bitwise identical, and writes per-job
//! latency / throughput / reuse-rate metrics to `BENCH_serve.json`.
//! With `BENCH_ASSERT_REUSE=1` it additionally gates on the reuse
//! counters (≥1 operand-cache hit, ≥1 warm workspace reuse, ≥1
//! exercised rejection, zero rework, zero failures) — the CI
//! `serve-stress` contract.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::algo::incremental::IncrementalSvd;
use crate::algo::lancsvd::{lancsvd, lancsvd_with};
use crate::algo::randsvd::randsvd_with;
use crate::algo::{InitDist, LancSvdOpts, RandSvdOpts, Restart, TruncatedSvd};
use crate::backend::cpu::CpuBackend;
use crate::backend::{Backend, Operand};
use crate::coordinator::driver::{make_send_backend_at, Algo, Params, SendBackendChoice};
use crate::error::{Error, Result};
use crate::gen::dense::paper_dense;
use crate::gen::sparse::{generate, SparseSpec};
use crate::gen::suite::Suite;
use crate::la::mat::Mat;
use crate::la::workspace::{Plan, PlanKind, Workspace};
use crate::metrics::percentile;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::scalar::{DType, Scalar};

fn perr(detail: impl Into<String>) -> Error {
    Error::Parse { what: "serve", detail: detail.into() }
}

/// Poison-proof lock: a panicking job is already contained by
/// `catch_unwind`, so a poisoned mutex carries no extra information —
/// take the inner guard.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

// ---------------------------------------------------------------------------
// Shape classes
// ---------------------------------------------------------------------------

/// The workspace-reuse key: two jobs share warm arenas iff their plans
/// are interchangeable, i.e. same algorithm kind, operand shape, solve
/// parameters that size buffers, and element precision. `p` is part of
/// the class even though it sizes no buffer: backends may stage
/// per-iteration device queues from it ([`Plan`] carries it), so plans
/// differing only in `p` are distinct classes by design.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    pub kind: PlanKind,
    pub m: usize,
    pub n: usize,
    pub r: usize,
    pub p: usize,
    pub b: usize,
    pub dtype: DType,
}

impl ShapeClass {
    /// The class a job schedules under.
    pub fn of(spec: &JobSpec) -> ShapeClass {
        let (m, n) = spec.operand.shape();
        let kind = match spec.kind {
            JobKind::Solve => match spec.algo {
                Algo::Lanc => PlanKind::LancSvd,
                Algo::Rand => PlanKind::RandSvd,
            },
            // Stream jobs (append/query/finalize) all schedule under
            // the incremental plan, so same-stream jobs share one FIFO
            // sub-queue: submission order IS stream order.
            _ => PlanKind::Incremental,
        };
        ShapeClass {
            kind,
            m,
            n,
            r: spec.params.r,
            p: spec.params.p,
            b: spec.params.b,
            dtype: spec.params.dtype,
        }
    }

    /// The buffer plan every workspace of this class is built from.
    pub fn plan(&self) -> Plan {
        match self.kind {
            PlanKind::LancSvd => Plan::lancsvd(self.m, self.n, self.r, self.p, self.b),
            PlanKind::RandSvd => Plan::randsvd(self.m, self.n, self.r, self.p, self.b),
            PlanKind::Orth => Plan::orth(self.m, self.r, self.b),
            PlanKind::Incremental => Plan::incremental(self.m, self.n, self.r, self.b),
        }
    }

    /// Human-readable class tag for results and metrics
    /// (`lanc:400x160:r16:p2:b8:f64`).
    pub fn label(&self) -> String {
        let kind = match self.kind {
            PlanKind::LancSvd => "lanc",
            PlanKind::RandSvd => "rand",
            PlanKind::Orth => "orth",
            PlanKind::Incremental => "inc",
        };
        format!(
            "{kind}:{}x{}:r{}:p{}:b{}:{}",
            self.m,
            self.n,
            self.r,
            self.p,
            self.b,
            self.dtype.name()
        )
    }
}

// ---------------------------------------------------------------------------
// Precision erasure
// ---------------------------------------------------------------------------

/// A workspace of either serving precision (pool storage).
pub enum AnyWorkspace {
    F32(Workspace<f32>),
    F64(Workspace<f64>),
}

/// A built backend of either serving precision (operand-cache storage).
/// Backends must be `Send`: they cross solver threads and outlive the
/// job that built them. The XLA backend (thread-bound `Rc<Runtime>`)
/// is structurally excluded — serve only accepts [`SendBackendChoice`].
pub enum AnyBackend {
    F32(Box<dyn Backend<f32> + Send>),
    F64(Box<dyn Backend<f64> + Send>),
}

/// A warm incremental basis of either serving precision — the whole
/// streaming-tenant state (U, σ, V, cols_seen) an operand-cache slot
/// keeps between `append`/`query` jobs.
pub enum AnyBasis {
    F32(IncrementalSvd<f32>),
    F64(IncrementalSvd<f64>),
}

impl AnyBasis {
    /// Leading ≤ `wanted` singular values (as f64 bits — the
    /// determinism comparison runs on these) and the stream length.
    fn sigma_snapshot(&self, wanted: usize) -> (Vec<f64>, usize) {
        match self {
            AnyBasis::F64(inc) => {
                (inc.sigma().iter().take(wanted).map(|x| x.to_f64()).collect(), inc.cols_seen())
            }
            AnyBasis::F32(inc) => {
                (inc.sigma().iter().take(wanted).map(|x| x.to_f64()).collect(), inc.cols_seen())
            }
        }
    }
}

/// The two precisions the server dispatches over. Monomorphizes the
/// execution path while the queue/caches stay type-erased.
pub trait ServeScalar: Scalar {
    const DTYPE: DType;
    /// Convert the canonical f64 operand to this precision. For f64
    /// this is an `Arc` bump (identity — and generation stamp —
    /// preserved); for f32 a one-time cast, built at most once per
    /// cache key because the slot lock covers the build.
    fn specialize(op: &Operand<f64>) -> Operand<Self>;
    fn wrap_ws(ws: Workspace<Self>) -> AnyWorkspace;
    fn unwrap_ws(any: AnyWorkspace) -> Option<Workspace<Self>>;
    fn wrap_be(be: Box<dyn Backend<Self> + Send>) -> AnyBackend;
    fn unwrap_be(any: AnyBackend) -> Option<Box<dyn Backend<Self> + Send>>;
    fn wrap_basis(b: IncrementalSvd<Self>) -> AnyBasis;
    fn unwrap_basis(any: AnyBasis) -> Option<IncrementalSvd<Self>>;
}

impl ServeScalar for f64 {
    const DTYPE: DType = DType::F64;
    fn specialize(op: &Operand<f64>) -> Operand<f64> {
        op.clone()
    }
    fn wrap_ws(ws: Workspace<f64>) -> AnyWorkspace {
        AnyWorkspace::F64(ws)
    }
    fn unwrap_ws(any: AnyWorkspace) -> Option<Workspace<f64>> {
        match any {
            AnyWorkspace::F64(ws) => Some(ws),
            AnyWorkspace::F32(_) => None,
        }
    }
    fn wrap_be(be: Box<dyn Backend<f64> + Send>) -> AnyBackend {
        AnyBackend::F64(be)
    }
    fn unwrap_be(any: AnyBackend) -> Option<Box<dyn Backend<f64> + Send>> {
        match any {
            AnyBackend::F64(be) => Some(be),
            AnyBackend::F32(_) => None,
        }
    }
    fn wrap_basis(b: IncrementalSvd<f64>) -> AnyBasis {
        AnyBasis::F64(b)
    }
    fn unwrap_basis(any: AnyBasis) -> Option<IncrementalSvd<f64>> {
        match any {
            AnyBasis::F64(b) => Some(b),
            AnyBasis::F32(_) => None,
        }
    }
}

impl ServeScalar for f32 {
    const DTYPE: DType = DType::F32;
    fn specialize(op: &Operand<f64>) -> Operand<f32> {
        op.cast()
    }
    fn wrap_ws(ws: Workspace<f32>) -> AnyWorkspace {
        AnyWorkspace::F32(ws)
    }
    fn unwrap_ws(any: AnyWorkspace) -> Option<Workspace<f32>> {
        match any {
            AnyWorkspace::F32(ws) => Some(ws),
            AnyWorkspace::F64(_) => None,
        }
    }
    fn wrap_be(be: Box<dyn Backend<f32> + Send>) -> AnyBackend {
        AnyBackend::F32(be)
    }
    fn unwrap_be(any: AnyBackend) -> Option<Box<dyn Backend<f32> + Send>> {
        match any {
            AnyBackend::F32(be) => Some(be),
            AnyBackend::F64(_) => None,
        }
    }
    fn wrap_basis(b: IncrementalSvd<f32>) -> AnyBasis {
        AnyBasis::F32(b)
    }
    fn unwrap_basis(any: AnyBasis) -> Option<IncrementalSvd<f32>> {
        match any {
            AnyBasis::F32(b) => Some(b),
            AnyBasis::F64(_) => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Workspace pool
// ---------------------------------------------------------------------------

/// Per-class pool counters (exposed via [`Server::class_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassStats {
    /// Cold `Workspace::new` constructions.
    pub created: u64,
    /// Checkouts satisfied by a warm, previously-used arena.
    pub warm_reuses: u64,
}

#[derive(Default)]
struct ClassPool {
    free: Vec<AnyWorkspace>,
    stats: ClassStats,
}

/// Warm solve arenas keyed by [`ShapeClass`]. Checkout pops a warm
/// arena when one is free (counted as a reuse) and otherwise
/// constructs cold *outside* the pool lock; checkin keeps at most
/// `max_free_per_class` arenas warm and reports whether the workspace
/// was retained.
pub struct WorkspacePool {
    classes: Mutex<HashMap<ShapeClass, ClassPool>>,
    max_free_per_class: usize,
}

impl WorkspacePool {
    fn new(max_free_per_class: usize) -> WorkspacePool {
        WorkspacePool {
            classes: Mutex::new(HashMap::new()),
            max_free_per_class: max_free_per_class.max(1),
        }
    }

    /// `(workspace, was_warm)`.
    fn checkout<S: ServeScalar>(&self, class: &ShapeClass) -> (Workspace<S>, bool) {
        {
            let mut map = lock(&self.classes);
            let cp = map.entry(*class).or_default();
            while let Some(any) = cp.free.pop() {
                if let Some(ws) = S::unwrap_ws(any) {
                    cp.stats.warm_reuses += 1;
                    return (ws, true);
                }
                // Precision mismatch cannot happen (dtype is part of the
                // class key); if it somehow did, dropping the stranger
                // and continuing is the safe direction.
            }
            cp.stats.created += 1;
        }
        // Cold build outside the lock: first-touch banding walks the
        // whole arena and must not serialize the other workers.
        (Workspace::new(class.plan()), false)
    }

    /// `true` when the workspace was retained for reuse.
    fn checkin(&self, class: &ShapeClass, ws: AnyWorkspace) -> bool {
        let mut map = lock(&self.classes);
        let cp = map.entry(*class).or_default();
        if cp.free.len() < self.max_free_per_class {
            cp.free.push(ws);
            true
        } else {
            false
        }
    }

    fn snapshot(&self) -> Vec<(ShapeClass, ClassStats, usize)> {
        let map = lock(&self.classes);
        map.iter().map(|(c, p)| (*c, p.stats, p.free.len())).collect()
    }
}

// ---------------------------------------------------------------------------
// Operand cache
// ---------------------------------------------------------------------------

/// One operand-cache slot. `built_ever` is flipped the first time a
/// backend build *succeeds* under this slot's lock; together with the
/// lock being held across build and solve it makes the hit/miss/rework
/// classification a pure function of slot state — independent of which
/// concurrent same-key job wins the lock first:
///
/// * `be` present            ⇒ hit;
/// * `be` absent, never built ⇒ miss (the one first build per key);
/// * `be` absent, built once  ⇒ rework (a panic discarded the backend).
struct SlotState {
    be: Option<AnyBackend>,
    /// Streaming tenants only: the warm incremental basis. Presence
    /// classifies hit/miss/rework for stream jobs exactly as `be` does
    /// for solves (a panic mid-append discards it; `finalize` clears it
    /// *and* `built_ever`, retiring the tenant cleanly).
    basis: Option<AnyBasis>,
    built_ever: bool,
}

type BackendSlot = Arc<Mutex<SlotState>>;

/// Warm built backends keyed by
/// `"{operand identity}|{dtype}|{backend}"`. Each key owns one *slot*
/// whose mutex is held across build **and** solve: concurrent jobs on
/// the same operand serialize on the slot instead of building duplicate
/// backends, which is both the cheap choice (one explicit-transpose
/// build, ever) and what makes the hit/miss counters deterministic.
struct OperandCache {
    slots: Mutex<HashMap<String, BackendSlot>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// A previously-built backend was gone at lock time (a panic
    /// discarded it). Zero in any healthy workload.
    rework: AtomicU64,
}

impl OperandCache {
    fn new() -> OperandCache {
        OperandCache {
            slots: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            rework: AtomicU64::new(0),
        }
    }

    fn slot(&self, key: &str) -> BackendSlot {
        let mut map = lock(&self.slots);
        match map.get(key) {
            Some(s) => Arc::clone(s),
            None => {
                let s: BackendSlot = Arc::new(Mutex::new(SlotState {
                    be: None,
                    basis: None,
                    built_ever: false,
                }));
                map.insert(key.to_string(), Arc::clone(&s));
                s
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Jobs
// ---------------------------------------------------------------------------

/// What a job asks the server to do: a one-shot solve, or one of the
/// streaming-tenant verbs (see the module docs, § Streaming tenants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// One-shot truncated SVD of the operand (the classic tenant).
    Solve,
    /// Absorb the next [`JobSpec::append_cols`] columns of the operand
    /// into the stream's warm incremental basis.
    Append,
    /// Read the warm basis's leading singular values; touches neither
    /// the operand nor a workspace.
    Query,
    /// Report the final spectrum and retire the stream tenant.
    Finalize,
}

/// One truncated-SVD request.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Caller-chosen correlation id (echoed in the result).
    pub id: String,
    pub kind: JobKind,
    /// Stream-tenant name (required for every non-`Solve` kind); keys
    /// the warm basis as `stream:NAME|dtype|backend`.
    pub stream: Option<String>,
    /// `Append` only: how many operand columns this job absorbs.
    pub append_cols: usize,
    pub algo: Algo,
    pub params: Params,
    /// Canonical f64 operand; converted per job dtype at backend build.
    pub operand: Operand<f64>,
    /// Operand-cache key override for operands without intrinsic
    /// identity (dense matrices). The protocol layer sets this to the
    /// canonical operand-spec JSON, which is content-determining for
    /// every generative spec. `None` + a dense operand ⇒ the job runs
    /// uncached (counted as a miss).
    pub operand_tag: Option<String>,
    /// Admission + queue deadline. `Some(0)` rejects at admission
    /// (deterministically — the CI rejection gate); otherwise jobs
    /// still queued past the deadline are rejected at dequeue.
    pub deadline: Option<Duration>,
    /// Fault injection (tests): panic mid-solve inside the worker.
    pub inject_panic: bool,
    /// Fault injection (tests): sleep before solving, to hold a worker
    /// and force queueing behavior.
    pub inject_delay: Option<Duration>,
}

impl JobSpec {
    pub fn new(
        id: impl Into<String>,
        algo: Algo,
        params: Params,
        operand: Operand<f64>,
    ) -> JobSpec {
        JobSpec {
            id: id.into(),
            kind: JobKind::Solve,
            stream: None,
            append_cols: 0,
            algo,
            params,
            operand,
            operand_tag: None,
            deadline: None,
            inject_panic: false,
            inject_delay: None,
        }
    }

    /// An `append` job: absorb the next `cols` columns of `operand`
    /// (the stream source) into `stream`'s warm basis. `params.r` is
    /// the rank cap, `params.b` the update block width, `params.tol`
    /// the σ threshold.
    pub fn append(
        id: impl Into<String>,
        stream: impl Into<String>,
        params: Params,
        operand: Operand<f64>,
        cols: usize,
    ) -> JobSpec {
        let mut s = JobSpec::new(id, Algo::Lanc, params, operand);
        s.kind = JobKind::Append;
        s.stream = Some(stream.into());
        s.append_cols = cols;
        s
    }

    /// A `query` job: snapshot `stream`'s warm spectrum. The operand is
    /// only used for shape-class bookkeeping (pass the stream source).
    pub fn query(
        id: impl Into<String>,
        stream: impl Into<String>,
        params: Params,
        operand: Operand<f64>,
    ) -> JobSpec {
        let mut s = JobSpec::new(id, Algo::Lanc, params, operand);
        s.kind = JobKind::Query;
        s.stream = Some(stream.into());
        s
    }

    /// A `finalize` job: report the final spectrum and retire the
    /// stream tenant (basis and backend dropped, slot reset).
    pub fn finalize(
        id: impl Into<String>,
        stream: impl Into<String>,
        params: Params,
        operand: Operand<f64>,
    ) -> JobSpec {
        let mut s = JobSpec::new(id, Algo::Lanc, params, operand);
        s.kind = JobKind::Finalize;
        s.stream = Some(stream.into());
        s
    }
}

/// Terminal state of a job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Done,
    /// The solve ran and errored (validation, breakdown, panic) — the
    /// server and its pools remain healthy.
    Failed(String),
    /// The job never ran: backpressure, expired deadline, or shutdown.
    Rejected(String),
}

impl JobStatus {
    pub fn tag(&self) -> &'static str {
        match self {
            JobStatus::Done => "ok",
            JobStatus::Failed(_) => "failed",
            JobStatus::Rejected(_) => "rejected",
        }
    }
}

/// What a job returns (also the replay record).
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: String,
    pub status: JobStatus,
    /// Leading `wanted` singular values (f64 bits are exact for both
    /// precisions — the determinism comparison runs on these).
    pub sigma: Vec<f64>,
    pub est_residuals: Vec<f64>,
    pub iters: usize,
    /// Dequeue-to-completion seconds.
    pub secs: f64,
    /// Submission-to-dequeue seconds.
    pub queue_secs: f64,
    pub shape_class: String,
    /// Stream jobs: total columns the basis had absorbed when this job
    /// completed (0 for solves).
    pub cols_seen: usize,
    /// The operand cache held a warm backend for this job's key.
    pub operand_hit: bool,
    /// The workspace checkout was satisfied by a warm arena.
    pub workspace_warm: bool,
}

impl JobResult {
    fn sync(id: String, status: JobStatus) -> JobResult {
        JobResult {
            id,
            status,
            sigma: Vec::new(),
            est_residuals: Vec::new(),
            iters: 0,
            secs: 0.0,
            queue_secs: 0.0,
            shape_class: String::new(),
            cols_seen: 0,
            operand_hit: false,
            workspace_warm: false,
        }
    }
}

/// Receipt for a submitted job.
pub struct JobHandle {
    pub id: String,
    rx: Receiver<JobResult>,
}

impl JobHandle {
    /// Block until the job reaches a terminal state.
    pub fn wait(self) -> JobResult {
        let JobHandle { id, rx } = self;
        match rx.recv() {
            Ok(r) => r,
            Err(_) => JobResult::sync(
                id,
                JobStatus::Failed("server dropped before the job completed".into()),
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Solver worker threads (each runs one job at a time; the inner
    /// thread pool is shared, so keep this small).
    pub solvers: usize,
    /// Bounded-queue capacity; submissions beyond it are rejected.
    pub queue_cap: usize,
    /// Backend family for every job (must be `Send`; see
    /// [`make_send_backend_at`] for the determinism-driven transpose
    /// policy).
    pub backend: SendBackendChoice,
    /// Deadline applied to jobs that do not carry their own.
    pub default_deadline: Option<Duration>,
    /// Warm arenas retained per shape class.
    pub max_free_ws_per_class: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            solvers: 2,
            queue_cap: 16,
            backend: SendBackendChoice::Cpu,
            default_deadline: None,
            max_free_ws_per_class: 4,
        }
    }
}

/// Monotonic counter snapshot ([`Server::counters`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeCounters {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected_backpressure: u64,
    pub rejected_deadline: u64,
    pub operand_hits: u64,
    pub operand_misses: u64,
    pub operand_rework: u64,
    pub ws_created: u64,
    pub ws_warm_reuses: u64,
    pub ws_discarded: u64,
    pub restart_yields: u64,
    pub stream_appends: u64,
    pub stream_queries: u64,
}

#[derive(Default)]
struct ServeStats {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    rejected_backpressure: AtomicU64,
    rejected_deadline: AtomicU64,
    ws_discarded: AtomicU64,
    restart_yields: AtomicU64,
    stream_appends: AtomicU64,
    stream_queries: AtomicU64,
}

struct Queued {
    spec: JobSpec,
    tx: Sender<JobResult>,
    submitted: Instant,
    deadline: Option<Instant>,
    class: ShapeClass,
}

/// Fair-within-class scheduler state: FIFO sub-queue per shape class,
/// round-robin over the non-empty classes.
struct SchedState {
    order: VecDeque<ShapeClass>,
    queues: HashMap<ShapeClass, VecDeque<Queued>>,
    queued: usize,
    open: bool,
}

impl SchedState {
    fn push(&mut self, q: Queued) {
        let class = q.class;
        let dq = self.queues.entry(class).or_default();
        if dq.is_empty() {
            self.order.push_back(class);
        }
        dq.push_back(q);
        self.queued += 1;
    }

    fn pop(&mut self) -> Option<Queued> {
        let class = self.order.pop_front()?;
        let dq = self.queues.get_mut(&class)?;
        let job = dq.pop_front();
        if dq.is_empty() {
            self.queues.remove(&class);
        } else {
            // Rotate: the class goes to the back so co-tenant classes
            // interleave.
            self.order.push_back(class);
        }
        if job.is_some() {
            self.queued -= 1;
        }
        job
    }
}

struct ServerInner {
    cfg: ServeConfig,
    sched: Mutex<SchedState>,
    available: Condvar,
    ws_pool: WorkspacePool,
    cache: OperandCache,
    stats: ServeStats,
}

/// The long-running multi-tenant solver (see module docs).
pub struct Server {
    inner: Arc<ServerInner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    pub fn new(cfg: ServeConfig) -> Server {
        let solvers = cfg.solvers.max(1);
        let inner = Arc::new(ServerInner {
            ws_pool: WorkspacePool::new(cfg.max_free_ws_per_class),
            cfg,
            sched: Mutex::new(SchedState {
                order: VecDeque::new(),
                queues: HashMap::new(),
                queued: 0,
                open: true,
            }),
            available: Condvar::new(),
            cache: OperandCache::new(),
            stats: ServeStats::default(),
        });
        let workers = (0..solvers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("trunksvd-serve-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("serve: failed to spawn solver thread")
            })
            .collect();
        Server { inner, workers }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.inner.cfg
    }

    /// Submit a job; the handle resolves to its [`JobResult`].
    pub fn submit(&self, spec: JobSpec) -> JobHandle {
        let (tx, rx) = channel();
        let id = spec.id.clone();
        self.submit_with(spec, tx);
        JobHandle { id, rx }
    }

    /// Submit with a caller-owned result channel (the protocol layer
    /// funnels every connection's jobs into one writer this way). The
    /// admission decision — and any rejection — happens synchronously.
    pub fn submit_with(&self, spec: JobSpec, tx: Sender<JobResult>) {
        self.inner.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let class = ShapeClass::of(&spec);
        let deadline = spec.deadline.or(self.inner.cfg.default_deadline);

        if let Some(d) = deadline {
            if d.is_zero() {
                self.inner.stats.rejected_deadline.fetch_add(1, Ordering::Relaxed);
                let mut r = JobResult::sync(
                    spec.id.clone(),
                    JobStatus::Rejected("deadline expired before admission".into()),
                );
                r.shape_class = class.label();
                let _ = tx.send(r);
                return;
            }
        }

        let mut s = lock(&self.inner.sched);
        if !s.open {
            drop(s);
            self.inner.stats.rejected_backpressure.fetch_add(1, Ordering::Relaxed);
            let mut r = JobResult::sync(
                spec.id.clone(),
                JobStatus::Rejected("server is shutting down".into()),
            );
            r.shape_class = class.label();
            let _ = tx.send(r);
            return;
        }
        if s.queued >= self.inner.cfg.queue_cap {
            let depth = s.queued;
            drop(s);
            self.inner.stats.rejected_backpressure.fetch_add(1, Ordering::Relaxed);
            let mut r = JobResult::sync(
                spec.id.clone(),
                JobStatus::Rejected(format!(
                    "queue full ({depth}/{} jobs queued)",
                    self.inner.cfg.queue_cap
                )),
            );
            r.shape_class = class.label();
            let _ = tx.send(r);
            return;
        }
        s.push(Queued {
            deadline: deadline.map(|d| now + d),
            spec,
            tx,
            submitted: now,
            class,
        });
        drop(s);
        self.inner.available.notify_one();
    }

    /// Jobs admitted but not yet dequeued by a worker (tests and
    /// load-shedding probes poll this).
    pub fn queue_depth(&self) -> usize {
        lock(&self.inner.sched).queued
    }

    /// Counter snapshot (monotonic across the server's lifetime).
    pub fn counters(&self) -> ServeCounters {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let (mut created, mut warm) = (0, 0);
        for (_, s, _) in self.inner.ws_pool.snapshot() {
            created += s.created;
            warm += s.warm_reuses;
        }
        ServeCounters {
            submitted: ld(&self.inner.stats.submitted),
            completed: ld(&self.inner.stats.completed),
            failed: ld(&self.inner.stats.failed),
            rejected_backpressure: ld(&self.inner.stats.rejected_backpressure),
            rejected_deadline: ld(&self.inner.stats.rejected_deadline),
            operand_hits: ld(&self.inner.cache.hits),
            operand_misses: ld(&self.inner.cache.misses),
            operand_rework: ld(&self.inner.cache.rework),
            ws_created: created,
            ws_warm_reuses: warm,
            ws_discarded: ld(&self.inner.stats.ws_discarded),
            restart_yields: ld(&self.inner.stats.restart_yields),
            stream_appends: ld(&self.inner.stats.stream_appends),
            stream_queries: ld(&self.inner.stats.stream_queries),
        }
    }

    /// Per-class `(label, stats, free arenas)` snapshot.
    pub fn class_stats(&self) -> Vec<(String, ClassStats, usize)> {
        let mut v: Vec<_> = self
            .inner
            .ws_pool
            .snapshot()
            .into_iter()
            .map(|(c, s, free)| (c.label(), s, free))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Stop admitting, drain the queue, and join the workers. Queued
    /// jobs still run to completion; only *new* submissions are
    /// rejected. Idempotent; also invoked by `Drop`.
    pub fn shutdown(&mut self) {
        {
            let mut s = lock(&self.inner.sched);
            s.open = false;
        }
        self.inner.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

fn worker_loop(inner: Arc<ServerInner>) {
    // Restart-boundary yield: the algorithms call back between outer
    // iterations (numerically inert points), letting the OS interleave
    // co-tenant solver threads there and letting us count the
    // safepoints actually reached.
    let hook_inner = Arc::clone(&inner);
    pool::set_restart_yield_hook(Some(Box::new(move || {
        hook_inner.stats.restart_yields.fetch_add(1, Ordering::Relaxed);
        std::thread::yield_now();
    })));

    loop {
        let job = {
            let mut s = lock(&inner.sched);
            loop {
                if let Some(j) = s.pop() {
                    break Some(j);
                }
                if !s.open {
                    break None;
                }
                s = inner.available.wait(s).unwrap_or_else(|p| p.into_inner());
            }
        };
        match job {
            Some(q) => run_job(&inner, q),
            None => break,
        }
    }
    pool::set_restart_yield_hook(None);
}

/// What one executed job produced (pre-assembly of [`JobResult`]).
struct Executed {
    status: JobStatus,
    sigma: Vec<f64>,
    est_residuals: Vec<f64>,
    iters: usize,
    cols_seen: usize,
    operand_hit: bool,
    workspace_warm: bool,
}

impl Executed {
    fn failed(msg: String, operand_hit: bool) -> Executed {
        Executed {
            status: JobStatus::Failed(msg),
            sigma: Vec::new(),
            est_residuals: Vec::new(),
            iters: 0,
            cols_seen: 0,
            operand_hit,
            workspace_warm: false,
        }
    }
}

fn run_job(inner: &ServerInner, q: Queued) {
    let start = Instant::now();
    let queue_secs = start.duration_since(q.submitted).as_secs_f64();
    let class_label = q.class.label();

    // Deadline re-check at dequeue: the job may have aged out while
    // queued behind slower tenants.
    if let Some(dl) = q.deadline {
        if start > dl {
            inner.stats.rejected_deadline.fetch_add(1, Ordering::Relaxed);
            let mut r = JobResult::sync(
                q.spec.id.clone(),
                JobStatus::Rejected(format!(
                    "deadline exceeded after {:.0} ms in queue",
                    queue_secs * 1e3
                )),
            );
            r.queue_secs = queue_secs;
            r.shape_class = class_label;
            let _ = q.tx.send(r);
            return;
        }
    }

    let ex = match (q.spec.kind, q.spec.params.dtype) {
        (JobKind::Solve, DType::F64) => execute_typed::<f64>(inner, &q),
        (JobKind::Solve, DType::F32) => execute_typed::<f32>(inner, &q),
        (_, DType::F64) => execute_stream_typed::<f64>(inner, &q),
        (_, DType::F32) => execute_stream_typed::<f32>(inner, &q),
    };
    match ex.status {
        JobStatus::Done => inner.stats.completed.fetch_add(1, Ordering::Relaxed),
        _ => inner.stats.failed.fetch_add(1, Ordering::Relaxed),
    };
    let _ = q.tx.send(JobResult {
        id: q.spec.id.clone(),
        status: ex.status,
        sigma: ex.sigma,
        est_residuals: ex.est_residuals,
        iters: ex.iters,
        secs: start.elapsed().as_secs_f64(),
        queue_secs,
        shape_class: class_label,
        cols_seen: ex.cols_seen,
        operand_hit: ex.operand_hit,
        workspace_warm: ex.workspace_warm,
    });
}

fn execute_typed<S: ServeScalar>(inner: &ServerInner, q: &Queued) -> Executed {
    let spec = &q.spec;

    // Operand-cache key: caller tag wins (it is the only identity a
    // dense operand has), else the operand's intrinsic identity. The
    // dtype and backend family are part of the key because the cached
    // value is a *built backend*, not the operand.
    let key = spec
        .operand_tag
        .clone()
        .or_else(|| spec.operand.identity_key())
        .map(|k| format!("{k}|{}|{}", S::DTYPE.name(), inner.cfg.backend.name()));

    let slot = key.as_deref().map(|k| inner.cache.slot(k));
    // The slot guard is held across build AND solve: a concurrent job
    // on the same operand waits here and then finds both the warm
    // backend and (because checkin happens before this guard drops) a
    // warm workspace. Classification reads only slot state (see
    // [`SlotState`]), so the counters come out the same no matter how
    // concurrent same-key jobs interleave.
    let mut guard = slot.as_ref().map(|s| lock(s));

    let operand_hit = match &guard {
        Some(g) if g.be.is_some() => {
            inner.cache.hits.fetch_add(1, Ordering::Relaxed);
            true
        }
        Some(g) if g.built_ever => {
            inner.cache.rework.fetch_add(1, Ordering::Relaxed);
            false
        }
        _ => {
            inner.cache.misses.fetch_add(1, Ordering::Relaxed);
            false
        }
    };

    let mut be: Box<dyn Backend<S> + Send> =
        match guard.as_mut().and_then(|g| g.be.take()).and_then(S::unwrap_be) {
            Some(be) => be,
            None => match make_send_backend_at::<S>(S::specialize(&spec.operand), inner.cfg.backend)
            {
                Ok(be) => be,
                Err(e) => return Executed::failed(format!("backend build: {e}"), operand_hit),
            },
        };
    // The build succeeded (or a warm backend was taken): from here on
    // an empty slot means a discarded backend, i.e. rework.
    if let Some(g) = guard.as_mut() {
        g.built_ever = true;
    }

    let (ws, workspace_warm) = inner.ws_pool.checkout::<S>(&q.class);

    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if let Some(d) = spec.inject_delay {
            std::thread::sleep(d);
        }
        if spec.inject_panic {
            panic!("injected panic (fault-injection test)");
        }
        solve_on(&mut *be, spec, &ws)
    }));

    match outcome {
        Ok(res) => {
            // Solve returned (Ok or clean Err): backend and workspace
            // are both in a reusable state. Order matters — check the
            // workspace in BEFORE releasing the slot guard, so a
            // same-operand waiter blocked on the slot is guaranteed to
            // find the warm arena.
            if !inner.ws_pool.checkin(&q.class, S::wrap_ws(ws)) {
                inner.stats.ws_discarded.fetch_add(1, Ordering::Relaxed);
            }
            if let Some(g) = guard.as_mut() {
                g.be = Some(S::wrap_be(be));
            }
            drop(guard);
            match res {
                Ok(svd) => {
                    let wanted = spec.params.wanted.min(svd.sigma.len());
                    Executed {
                        status: JobStatus::Done,
                        sigma: svd.sigma[..wanted].iter().map(|s| s.to_f64()).collect(),
                        est_residuals: svd.est_residuals,
                        iters: svd.iters,
                        cols_seen: 0,
                        operand_hit,
                        workspace_warm,
                    }
                }
                Err(e) => Executed {
                    status: JobStatus::Failed(e.to_string()),
                    sigma: Vec::new(),
                    est_residuals: Vec::new(),
                    iters: 0,
                    cols_seen: 0,
                    operand_hit,
                    workspace_warm,
                },
            }
        }
        Err(payload) => {
            // Panic mid-solve: the backend and workspace were torn at an
            // arbitrary point — discard both. The slot stays empty, so
            // the next same-key job rebuilds (counted as rework).
            drop(ws);
            drop(be);
            inner.stats.ws_discarded.fetch_add(1, Ordering::Relaxed);
            drop(guard);
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            Executed::failed(format!("solve panicked: {msg}"), operand_hit)
        }
    }
}

/// Execute one streaming-tenant job (`append`/`query`/`finalize`) —
/// see the module docs, § Streaming tenants. The stream's slot mutex is
/// held across the whole job, so same-stream jobs serialize and the
/// hit/miss/rework classification is a pure function of slot state,
/// exactly like the solve path.
fn execute_stream_typed<S: ServeScalar>(inner: &ServerInner, q: &Queued) -> Executed {
    let spec = &q.spec;
    let Some(name) = spec.stream.as_deref() else {
        return Executed::failed("stream job without a 'stream' name".into(), false);
    };
    let key = format!("stream:{name}|{}|{}", S::DTYPE.name(), inner.cfg.backend.name());
    let slot = inner.cache.slot(&key);
    let mut guard = lock(&slot);

    // Hit = a warm basis is present; rework = one existed and a panic
    // discarded it; miss = this stream never built (the first append —
    // or, after `finalize` reset the slot, the first of the next life).
    let operand_hit = if guard.basis.is_some() {
        inner.cache.hits.fetch_add(1, Ordering::Relaxed);
        true
    } else if guard.built_ever {
        inner.cache.rework.fetch_add(1, Ordering::Relaxed);
        false
    } else {
        inner.cache.misses.fetch_add(1, Ordering::Relaxed);
        false
    };

    match spec.kind {
        JobKind::Query => {
            inner.stats.stream_queries.fetch_add(1, Ordering::Relaxed);
            match guard.basis.as_ref() {
                Some(b) => {
                    let (sigma, cols_seen) = b.sigma_snapshot(spec.params.wanted);
                    Executed {
                        status: JobStatus::Done,
                        sigma,
                        est_residuals: Vec::new(),
                        iters: 0,
                        cols_seen,
                        operand_hit,
                        workspace_warm: false,
                    }
                }
                None => {
                    Executed::failed(format!("query on stream '{name}' with no basis"), operand_hit)
                }
            }
        }
        JobKind::Finalize => match guard.basis.take() {
            Some(b) => {
                let (sigma, cols_seen) = b.sigma_snapshot(spec.params.wanted);
                // Retire the tenant: drop the basis AND the backend,
                // and forget the slot ever built — a replayed workload's
                // first append is then a clean miss, not rework.
                guard.be = None;
                guard.built_ever = false;
                drop(guard);
                Executed {
                    status: JobStatus::Done,
                    sigma,
                    est_residuals: Vec::new(),
                    iters: 0,
                    cols_seen,
                    operand_hit,
                    workspace_warm: false,
                }
            }
            None => {
                Executed::failed(format!("finalize on stream '{name}' with no basis"), operand_hit)
            }
        },
        JobKind::Append => {
            inner.stats.stream_appends.fetch_add(1, Ordering::Relaxed);
            let p = &spec.params;
            let (m, n_total) = spec.operand.shape();
            let cols = spec.append_cols;
            if cols == 0 {
                return Executed::failed("append needs cols >= 1".into(), operand_hit);
            }
            if p.r < 1 || p.r > m {
                return Executed::failed(
                    format!("append rank cap {} outside 1..={m}", p.r),
                    operand_hit,
                );
            }
            let basis = match guard.basis.take().and_then(S::unwrap_basis) {
                Some(b) => b,
                None => IncrementalSvd::new(m, n_total, p.r, p.b.max(1), p.tol.unwrap_or(0.0)),
            };
            let start_col = basis.cols_seen();
            if start_col + cols > n_total {
                guard.basis = Some(S::wrap_basis(basis));
                return Executed::failed(
                    format!(
                        "append past the end of the stream source \
                         ({start_col} + {cols} > {n_total})"
                    ),
                    operand_hit,
                );
            }
            let mut be = match guard.be.take().and_then(S::unwrap_be) {
                Some(be) => be,
                None => {
                    match make_send_backend_at::<S>(S::specialize(&spec.operand), inner.cfg.backend)
                    {
                        Ok(be) => be,
                        Err(e) => {
                            guard.basis = Some(S::wrap_basis(basis));
                            return Executed::failed(format!("backend build: {e}"), operand_hit);
                        }
                    }
                }
            };
            // Build succeeded: from here an empty slot means a panic
            // discarded the basis, i.e. rework.
            guard.built_ever = true;
            let op = S::specialize(&spec.operand);
            let (ws, workspace_warm) = inner.ws_pool.checkout::<S>(&q.class);
            let block_cap = basis.block_cap();

            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                let mut basis = basis;
                if let Some(d) = spec.inject_delay {
                    std::thread::sleep(d);
                }
                if spec.inject_panic {
                    panic!("injected panic (fault-injection test)");
                }
                let mut res = Ok(());
                let mut j = 0;
                while j < cols {
                    let w = (cols - j).min(block_cap);
                    res = operand_columns(&op, start_col + j, w)
                        .and_then(|block| basis.update_with(&mut *be, block.as_ref(), &ws));
                    if res.is_err() {
                        break;
                    }
                    j += w;
                }
                (be, basis, ws, res)
            }));

            match outcome {
                Ok((be, basis, ws, res)) => {
                    // The update returned (Ok, or a clean Err that left
                    // the basis self-consistent — `update_with` commits
                    // its state only after every fallible step). Check
                    // the workspace in BEFORE the slot guard drops, as
                    // in the solve path.
                    if !inner.ws_pool.checkin(&q.class, S::wrap_ws(ws)) {
                        inner.stats.ws_discarded.fetch_add(1, Ordering::Relaxed);
                    }
                    let any = S::wrap_basis(basis);
                    let (sigma, cols_seen) = any.sigma_snapshot(spec.params.wanted);
                    guard.be = Some(S::wrap_be(be));
                    guard.basis = Some(any);
                    drop(guard);
                    match res {
                        Ok(()) => Executed {
                            status: JobStatus::Done,
                            sigma,
                            est_residuals: Vec::new(),
                            iters: cols.div_ceil(block_cap),
                            cols_seen,
                            operand_hit,
                            workspace_warm,
                        },
                        Err(e) => Executed {
                            status: JobStatus::Failed(e.to_string()),
                            sigma: Vec::new(),
                            est_residuals: Vec::new(),
                            iters: 0,
                            cols_seen,
                            operand_hit,
                            workspace_warm,
                        },
                    }
                }
                Err(payload) => {
                    // Panic mid-append: basis, backend, and workspace
                    // were all torn at an arbitrary point and died with
                    // the closure. The slot stays empty with
                    // `built_ever` set, so the next same-stream append
                    // rebuilds from scratch (counted as rework).
                    inner.stats.ws_discarded.fetch_add(1, Ordering::Relaxed);
                    drop(guard);
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".into());
                    Executed::failed(format!("append panicked: {msg}"), operand_hit)
                }
            }
        }
        JobKind::Solve => unreachable!("solve jobs dispatch to execute_typed"),
    }
}

/// Materialize columns `[j0, j0+w)` of an operand as a dense m×w block
/// (the staging copy an `append` feeds to the incremental update).
/// Sharded operands are rejected: a stream tenant's source must be
/// addressable by column.
fn operand_columns<S: ServeScalar>(op: &Operand<S>, j0: usize, w: usize) -> Result<Mat<S>> {
    let (m, n) = op.shape();
    if j0 + w > n {
        return Err(Error::InvalidParam(format!(
            "stream block [{j0}, {}) outside the operand's {n} columns",
            j0 + w
        )));
    }
    match op {
        Operand::Dense(a) => Ok(a.panel_owned(j0, w)),
        Operand::Sparse(a) => {
            let mut out = Mat::zeros(m, w);
            for i in 0..m {
                let (idx, vals) = a.row(i);
                for (c, v) in idx.iter().zip(vals) {
                    let c = *c as usize;
                    if c >= j0 && c < j0 + w {
                        out.set(i, c - j0, *v);
                    }
                }
            }
            Ok(out)
        }
        Operand::Sharded { .. } => Err(Error::InvalidParam(
            "stream appends need an in-core operand (dense|sparse), not shards".into(),
        )),
    }
}

/// Dispatch one solve on a cached backend through the allocation-free
/// `*_with` entry points (the serving layer never solves without a
/// pooled workspace).
fn solve_on<S: Scalar>(
    be: &mut dyn Backend<S>,
    spec: &JobSpec,
    ws: &Workspace<S>,
) -> Result<TruncatedSvd<S>> {
    let p = &spec.params;
    match spec.algo {
        Algo::Rand => randsvd_with(
            be,
            &RandSvdOpts {
                r: p.r,
                p: p.p,
                b: p.b,
                seed: p.seed,
                init: InitDist::CenteredPoisson,
                fuse: None,
            },
            ws,
        ),
        Algo::Lanc => lancsvd_with(
            be,
            &LancSvdOpts {
                r: p.r,
                p: p.p,
                b: p.b,
                seed: p.seed,
                init: InitDist::CenteredPoisson,
                tol: p.tol,
                wanted: p.wanted,
                restart: p.restart,
                fuse: None,
            },
            ws,
        ),
    }
}

// ---------------------------------------------------------------------------
// Line protocol
// ---------------------------------------------------------------------------

/// Per-connection-set protocol state: the operand-spec → operand memo
/// (identical specs must resolve to the *same* `Arc` so the operand
/// cache can hit) and the fallback job-id counter.
pub struct ProtocolState {
    operands: Mutex<HashMap<String, Operand<f64>>>,
    next_id: AtomicU64,
}

impl Default for ProtocolState {
    fn default() -> Self {
        ProtocolState::new()
    }
}

impl ProtocolState {
    pub fn new() -> ProtocolState {
        ProtocolState { operands: Mutex::new(HashMap::new()), next_id: AtomicU64::new(0) }
    }

    fn fresh_id(&self) -> String {
        format!("job-{}", self.next_id.fetch_add(1, Ordering::Relaxed) + 1)
    }

    /// Resolve an operand spec to `(operand, canonical tag)`. The tag
    /// is the compact JSON serialization of the spec — `Json::Obj` is a
    /// `BTreeMap`, so key order is canonical and equal specs produce
    /// equal tags. The memo is held across the build so a spec is built
    /// exactly once no matter how many connections race on it.
    pub fn resolve_operand(&self, spec: &Json) -> Result<(Operand<f64>, String)> {
        let tag = json::write(spec);
        let mut map = lock(&self.operands);
        if let Some(op) = map.get(&tag) {
            return Ok((op.clone(), tag));
        }
        let op = build_operand(spec)?;
        map.insert(tag.clone(), op.clone());
        Ok((op, tag))
    }
}

fn opt_usize(j: &Json, key: &str) -> Option<usize> {
    j.get(key).and_then(|v| v.as_usize())
}
fn opt_u64(j: &Json, key: &str) -> Option<u64> {
    j.get(key).and_then(|v| v.as_u64())
}
fn opt_f64(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}
fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| perr(format!("field '{key}' must be a number")))
}

/// Build an operand from its spec object (see module docs for the
/// accepted forms).
pub fn build_operand(spec: &Json) -> Result<Operand<f64>> {
    if let Some(name) = spec.get("suite").and_then(|v| v.as_str()) {
        let suite = Suite::load_default()?;
        let e = suite
            .sparse_by_name(name)
            .ok_or_else(|| perr(format!("unknown suite matrix '{name}'")))?;
        return Ok(Operand::sparse(generate(&e.spec)));
    }
    if let Some(path) = spec.get("mtx").and_then(|v| v.as_str()) {
        return Ok(Operand::sparse(crate::sparse::mm::read_csr(path)?));
    }
    if let Some(sp) = spec.get("sparse") {
        let d = SparseSpec::default();
        return Ok(Operand::sparse(generate(&SparseSpec {
            rows: req_usize(sp, "rows")?,
            cols: req_usize(sp, "cols")?,
            nnz: req_usize(sp, "nnz")?,
            seed: opt_u64(sp, "seed").unwrap_or(d.seed),
            skew: opt_f64(sp, "skew").unwrap_or(d.skew),
            value_decay: opt_f64(sp, "value_decay").unwrap_or(d.value_decay),
        })));
    }
    if let Some(dn) = spec.get("dense") {
        let m = req_usize(dn, "m")?;
        let n = req_usize(dn, "n")?;
        let seed = opt_u64(dn, "seed").unwrap_or(42);
        return Ok(Operand::dense(paper_dense(m, n, seed).a));
    }
    if let Some(dir) = spec.get("shards").and_then(|v| v.as_str()) {
        let cap = opt_usize(spec, "resident_cap").unwrap_or(0);
        let sd = crate::sparse::shard::ShardDir::open(dir)?;
        return Ok(Operand::sharded(Arc::new(sd), cap));
    }
    Err(perr("operand spec needs one of suite|mtx|sparse|dense|shards"))
}

/// Server-side defaults a job line is merged over.
#[derive(Clone, Debug)]
pub struct JobDefaults {
    pub algo: Algo,
    pub params: Params,
}

impl Default for JobDefaults {
    fn default() -> Self {
        JobDefaults { algo: Algo::Lanc, params: Params::default() }
    }
}

/// Merge a job (or workload `defaults`) object over the base defaults.
fn overlay(j: &Json, base: &JobDefaults) -> Result<(Algo, Params)> {
    let algo = match j.get("algo").and_then(|v| v.as_str()) {
        None => base.algo,
        Some("lanc" | "lancsvd") => Algo::Lanc,
        Some("rand" | "randsvd") => Algo::Rand,
        Some(other) => return Err(perr(format!("unknown algo '{other}' (lanc|rand)"))),
    };
    let restart = match j.get("restart").and_then(|v| v.as_str()) {
        None => base.params.restart,
        Some("basic") => Restart::Basic,
        Some("thick") => Restart::Thick { keep: opt_usize(j, "keep").unwrap_or(32) },
        Some(other) => return Err(perr(format!("unknown restart '{other}' (basic|thick)"))),
    };
    let dtype = match j.get("dtype").and_then(|v| v.as_str()) {
        None => base.params.dtype,
        Some(tag) => {
            DType::parse(tag).ok_or_else(|| perr(format!("unknown dtype '{tag}' (f32|f64)")))?
        }
    };
    let params = Params {
        r: opt_usize(j, "r").unwrap_or(base.params.r),
        p: opt_usize(j, "p").unwrap_or(base.params.p),
        b: opt_usize(j, "b").unwrap_or(base.params.b),
        seed: opt_u64(j, "seed").unwrap_or(base.params.seed),
        tol: opt_f64(j, "tol").or(base.params.tol),
        wanted: opt_usize(j, "wanted").unwrap_or(base.params.wanted),
        restart,
        dtype,
    };
    Ok((algo, params))
}

/// Parse one protocol line into a [`JobSpec`].
pub fn parse_job(line: &str, defaults: &JobDefaults, st: &ProtocolState) -> Result<JobSpec> {
    let j = json::parse(line)?;
    job_from_json(&j, defaults, st)
}

/// Build a [`JobSpec`] from a parsed job object.
pub fn job_from_json(j: &Json, defaults: &JobDefaults, st: &ProtocolState) -> Result<JobSpec> {
    let (algo, params) = overlay(j, defaults)?;
    let kind = match j.get("kind").and_then(|v| v.as_str()) {
        None | Some("solve") => JobKind::Solve,
        Some("append") => JobKind::Append,
        Some("query") => JobKind::Query,
        Some("finalize") => JobKind::Finalize,
        Some(other) => {
            return Err(perr(format!("unknown kind '{other}' (solve|append|query|finalize)")))
        }
    };
    let stream = j.get("stream").and_then(|v| v.as_str()).map(|s| s.to_string());
    if kind != JobKind::Solve && stream.is_none() {
        return Err(perr(format!("'{}' jobs need a 'stream' name", match kind {
            JobKind::Append => "append",
            JobKind::Query => "query",
            _ => "finalize",
        })));
    }
    let append_cols = opt_usize(j, "cols").unwrap_or(0);
    if kind == JobKind::Append && append_cols == 0 {
        return Err(perr("'append' jobs need 'cols' >= 1"));
    }
    let (operand, tag) = st.resolve_operand(j.req("operand")?)?;
    let id = match j.get("id").and_then(|v| v.as_str()) {
        Some(s) => s.to_string(),
        None => st.fresh_id(),
    };
    Ok(JobSpec {
        id,
        kind,
        stream,
        append_cols,
        algo,
        params,
        operand,
        operand_tag: Some(tag),
        deadline: opt_f64(j, "deadline_ms").map(|ms| Duration::from_secs_f64(ms.max(0.0) / 1e3)),
        inject_panic: j.get("inject_panic").and_then(|v| v.as_bool()).unwrap_or(false),
        inject_delay: opt_f64(j, "inject_delay_ms")
            .map(|ms| Duration::from_secs_f64(ms.max(0.0) / 1e3)),
    })
}

/// Serialize a result for the line protocol / replay report.
pub fn result_json(r: &JobResult) -> Json {
    let mut pairs = vec![
        ("id", json::str(r.id.clone())),
        ("status", json::str(r.status.tag())),
        ("sigma", json::arr(r.sigma.iter().map(|s| json::num(*s)).collect())),
        ("iters", json::num(r.iters as f64)),
        ("secs", json::num(r.secs)),
        ("queue_secs", json::num(r.queue_secs)),
        ("shape_class", json::str(r.shape_class.clone())),
        ("cols_seen", json::num(r.cols_seen as f64)),
        ("operand_hit", Json::Bool(r.operand_hit)),
        ("workspace_warm", Json::Bool(r.workspace_warm)),
    ];
    if let JobStatus::Failed(m) | JobStatus::Rejected(m) = &r.status {
        pairs.push(("error", json::str(m.clone())));
    }
    if !r.est_residuals.is_empty() {
        pairs.push((
            "est_residuals",
            json::arr(r.est_residuals.iter().map(|x| json::num(*x)).collect()),
        ));
    }
    json::obj(pairs)
}

fn parse_failure(st: &ProtocolState, e: &Error) -> JobResult {
    JobResult::sync(st.fresh_id(), JobStatus::Failed(format!("parse: {e}")))
}

/// Serve one connection: read line-delimited jobs from `input`, write
/// line-delimited results to `output` as they complete (a dedicated
/// writer thread keeps slow solves from blocking result delivery).
/// Unparseable lines produce a `failed` result and do not tear down
/// the connection. Returns after every submitted job has resolved.
pub fn serve_connection<R: BufRead, W: Write + Send>(
    server: &Server,
    st: &ProtocolState,
    defaults: &JobDefaults,
    input: R,
    output: &mut W,
) -> Result<()> {
    let (tx, rx) = channel::<JobResult>();
    std::thread::scope(|scope| -> Result<()> {
        let writer = scope.spawn(move || -> std::io::Result<()> {
            for r in rx {
                writeln!(output, "{}", json::write(&result_json(&r)))?;
                output.flush()?;
            }
            Ok(())
        });
        for line in input.lines() {
            let line = line.map_err(|e| Error::Io { path: "<serve input>".into(), source: e })?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            match parse_job(line, defaults, st) {
                Ok(spec) => server.submit_with(spec, tx.clone()),
                Err(e) => {
                    let _ = tx.send(parse_failure(st, &e));
                }
            }
        }
        // Closing our sender leaves one per in-flight job; the writer
        // drains until the last completes.
        drop(tx);
        match writer.join() {
            Ok(io) => io.map_err(|e| Error::Io { path: "<serve output>".into(), source: e }),
            Err(_) => Err(Error::InvalidParam("serve: writer thread panicked".into())),
        }
    })
}

/// In-memory convenience wrapper around [`serve_connection`] (tests,
/// and `serve` reading stdin via the CLI).
pub fn serve_lines(
    server: &Server,
    defaults: &JobDefaults,
    input: &str,
    output: &mut Vec<u8>,
) -> Result<()> {
    let st = ProtocolState::new();
    serve_connection(server, &st, defaults, std::io::Cursor::new(input.as_bytes()), output)
}

/// Accept connections on a unix socket, each served concurrently
/// against the shared server (and a shared operand memo, so tenants on
/// different connections still share staged operands). Runs until the
/// listener errors (or forever).
#[cfg(unix)]
pub fn serve_unix(server: &Server, socket_path: &str, defaults: &JobDefaults) -> Result<()> {
    use std::os::unix::net::UnixListener;
    let _ = std::fs::remove_file(socket_path);
    let listener = UnixListener::bind(socket_path)
        .map_err(|e| Error::Io { path: socket_path.to_string(), source: e })?;
    let st = ProtocolState::new();
    std::thread::scope(|scope| {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { break };
            let Ok(read_half) = stream.try_clone() else { continue };
            let st = &st;
            scope.spawn(move || {
                let mut out = stream;
                let _ = serve_connection(
                    server,
                    st,
                    defaults,
                    std::io::BufReader::new(read_half),
                    &mut out,
                );
            });
        }
    });
    Ok(())
}

// ---------------------------------------------------------------------------
// Workload replay
// ---------------------------------------------------------------------------

/// CLI overrides for a workload file's own settings.
#[derive(Clone, Debug, Default)]
pub struct ReplayOverrides {
    pub workers: Option<usize>,
    pub queue_cap: Option<usize>,
    pub repeat: Option<usize>,
    pub backend: Option<SendBackendChoice>,
}

/// What [`replay_file`] returns (the full report also lands in the
/// `--out` JSON).
#[derive(Clone, Debug)]
pub struct ReplaySummary {
    pub runs: usize,
    pub jobs_per_run: usize,
    pub counters: ServeCounters,
    /// Repeat runs produced bitwise-identical singular values per job
    /// id (vacuously true for a single run).
    pub deterministic: bool,
    pub wall_secs: f64,
    /// Streaming staleness check: `append` jobs audited against a
    /// from-scratch solve of the stream prefix (0 ⇒ no stream jobs).
    pub staleness_appends: usize,
    /// Worst relative σ error of the warm basis across those appends.
    pub staleness_max_rel: f64,
    /// Every audited append was within [`STALENESS_TOL`] (vacuously
    /// true with no appends).
    pub staleness_ok: bool,
}

/// The replay accuracy-vs-staleness gate: after every `append`, the
/// warm incremental basis must match a from-scratch solve of the same
/// stream prefix to this relative σ error.
pub const STALENESS_TOL: f64 = 1e-4;

/// Replay a workload file (see `config/workloads/README.md` for the
/// schema) `repeat` times over ONE warm server, verify repeat-run
/// bitwise determinism, and write the metrics report. Gates:
///
/// * `repeat > 1` and any per-id sigma mismatch ⇒ `Err` (always — the
///   report is still written first, for diagnosis);
/// * `BENCH_ASSERT_REUSE=1` ⇒ [`assert_reuse_gates`] on the final
///   counters.
pub fn replay_file(path: &str, out: Option<&str>, ov: &ReplayOverrides) -> Result<ReplaySummary> {
    let doc = json::parse_file(path)?;
    let workers = ov.workers.or_else(|| opt_usize(&doc, "workers")).unwrap_or(2);
    let queue_cap = ov.queue_cap.or_else(|| opt_usize(&doc, "queue_cap")).unwrap_or(16);
    let repeat = ov.repeat.or_else(|| opt_usize(&doc, "repeat")).unwrap_or(1).max(1);
    let backend = match ov.backend {
        Some(b) => b,
        None => match doc.get("backend").and_then(|v| v.as_str()) {
            None => SendBackendChoice::Cpu,
            Some(tag) => SendBackendChoice::parse(tag).ok_or_else(|| {
                perr(format!("unknown backend '{tag}' (cpu|cpu-scatter|cpu-expt|staged)"))
            })?,
        },
    };
    let mut defaults = JobDefaults::default();
    if let Some(d) = doc.get("defaults") {
        let (algo, params) = overlay(d, &defaults)?;
        defaults = JobDefaults { algo, params };
    }
    let jobs = doc
        .req("jobs")?
        .as_arr()
        .ok_or_else(|| perr("'jobs' must be an array"))?;

    let st = ProtocolState::new();
    let mut server = Server::new(ServeConfig {
        solvers: workers,
        queue_cap,
        backend,
        ..ServeConfig::default()
    });

    let t0 = Instant::now();
    let mut per_run: Vec<Vec<JobResult>> = Vec::new();
    for _ in 0..repeat {
        let base = Instant::now();
        let (tx, rx) = channel::<JobResult>();
        let mut records: Vec<JobResult> = Vec::new();
        for j in jobs {
            let at_ms = opt_f64(j, "at_ms").unwrap_or(0.0).max(0.0);
            let target = base + Duration::from_secs_f64(at_ms / 1e3);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            match job_from_json(j, &defaults, &st) {
                Ok(spec) => server.submit_with(spec, tx.clone()),
                Err(e) => records.push(parse_failure(&st, &e)),
            }
        }
        drop(tx);
        for r in rx {
            records.push(r);
        }
        per_run.push(records);
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    server.shutdown();

    // Bitwise determinism across repeat runs: per job id, `Done` in
    // both runs ⇒ identical sigma bit patterns.
    let mut mismatched: Vec<String> = Vec::new();
    if repeat > 1 {
        let first: HashMap<&str, &JobResult> =
            per_run[0].iter().map(|r| (r.id.as_str(), r)).collect();
        for later in &per_run[1..] {
            for r in later {
                let Some(f) = first.get(r.id.as_str()) else { continue };
                if f.status != JobStatus::Done || r.status != JobStatus::Done {
                    continue;
                }
                let a: Vec<u64> = f.sigma.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u64> = r.sigma.iter().map(|x| x.to_bits()).collect();
                if a != b && !mismatched.iter().any(|m| m == &r.id) {
                    mismatched.push(r.id.clone());
                }
            }
        }
    }
    let deterministic = mismatched.is_empty();

    // Accuracy-vs-staleness audit (run 0): replaying the appends in
    // workload order, after each one the served spectrum snapshot must
    // match a from-scratch solve of exactly the columns absorbed so
    // far. The reference is the value-level LancSVD on a fresh CPU
    // backend over the re-materialized prefix — fully independent of
    // the serve path and its warm state.
    let mut stale_entries: Vec<Json> = Vec::new();
    let mut stale_appends = 0usize;
    let mut stale_skipped = 0usize;
    let mut stale_max_rel = 0.0f64;
    {
        let first: HashMap<&str, &JobResult> =
            per_run[0].iter().map(|r| (r.id.as_str(), r)).collect();
        let mut cum: HashMap<String, usize> = HashMap::new();
        for j in jobs {
            if j.get("kind").and_then(|v| v.as_str()) != Some("append") {
                continue;
            }
            let Some(stream) = j.get("stream").and_then(|v| v.as_str()) else { continue };
            let cols = opt_usize(j, "cols").unwrap_or(0);
            let seen = cum.entry(stream.to_string()).or_insert(0);
            *seen += cols;
            let cum_cols = *seen;
            let Some(id) = j.get("id").and_then(|v| v.as_str()) else {
                stale_skipped += 1;
                continue;
            };
            let Some(r) = first.get(id) else { continue };
            if r.status != JobStatus::Done {
                continue; // a failed append already trips the reuse gates
            }
            let (_algo, params) = overlay(j, &defaults)?;
            let Some(dn) = j.req("operand")?.get("dense") else {
                // Only generative dense specs can be re-materialized
                // for the reference; anything else is reported, not
                // silently passed.
                stale_skipped += 1;
                continue;
            };
            let rel = staleness_reference(dn, cum_cols, &params, &r.sigma)?;
            stale_appends += 1;
            stale_max_rel = stale_max_rel.max(rel);
            stale_entries.push(json::obj(vec![
                ("id", json::str(id)),
                ("stream", json::str(stream)),
                ("cols_seen", json::num(cum_cols as f64)),
                ("rel_sigma_err", json::num(rel)),
            ]));
        }
    }
    let stale_ok = stale_max_rel <= STALENESS_TOL;

    let counters = server.counters();
    let done: Vec<f64> = per_run
        .iter()
        .flatten()
        .filter(|r| r.status == JobStatus::Done)
        .map(|r| r.secs)
        .collect();
    let throughput = done.len() as f64 / wall_secs.max(1e-9);

    let counters_json = json::obj(vec![
        ("submitted", json::num(counters.submitted as f64)),
        ("completed", json::num(counters.completed as f64)),
        ("failed", json::num(counters.failed as f64)),
        ("rejected_backpressure", json::num(counters.rejected_backpressure as f64)),
        ("rejected_deadline", json::num(counters.rejected_deadline as f64)),
        ("operand_hits", json::num(counters.operand_hits as f64)),
        ("operand_misses", json::num(counters.operand_misses as f64)),
        ("operand_rework", json::num(counters.operand_rework as f64)),
        ("ws_created", json::num(counters.ws_created as f64)),
        ("ws_warm_reuses", json::num(counters.ws_warm_reuses as f64)),
        ("ws_discarded", json::num(counters.ws_discarded as f64)),
        ("restart_yields", json::num(counters.restart_yields as f64)),
        ("stream_appends", json::num(counters.stream_appends as f64)),
        ("stream_queries", json::num(counters.stream_queries as f64)),
    ]);
    let classes_json = json::arr(
        server
            .class_stats()
            .into_iter()
            .map(|(label, s, free)| {
                json::obj(vec![
                    ("class", json::str(label)),
                    ("created", json::num(s.created as f64)),
                    ("warm_reuses", json::num(s.warm_reuses as f64)),
                    ("free", json::num(free as f64)),
                ])
            })
            .collect(),
    );
    let runs_json = json::arr(
        per_run
            .iter()
            .map(|run| json::arr(run.iter().map(result_json).collect()))
            .collect(),
    );
    let mut report_pairs = vec![
        ("workload", json::str(path)),
        ("threads", json::num(pool::num_threads() as f64)),
        ("workers", json::num(workers as f64)),
        ("queue_cap", json::num(queue_cap as f64)),
        ("backend", json::str(backend.name())),
        ("repeat", json::num(repeat as f64)),
        ("jobs_per_run", json::num(jobs.len() as f64)),
        ("wall_secs", json::num(wall_secs)),
        ("throughput_jobs_per_sec", json::num(throughput)),
        (
            "latency",
            json::obj(vec![
                ("p50_secs", json::num(percentile(&done, 50.0))),
                ("p95_secs", json::num(percentile(&done, 95.0))),
                ("max_secs", json::num(percentile(&done, 100.0))),
            ]),
        ),
        ("counters", counters_json),
        ("classes", classes_json),
        (
            "determinism",
            json::obj(vec![
                ("repeat", json::num(repeat as f64)),
                ("bitwise_identical", Json::Bool(deterministic)),
                (
                    "mismatched_ids",
                    json::arr(mismatched.iter().map(|s| json::str(s.clone())).collect()),
                ),
            ]),
        ),
    ];
    if stale_appends + stale_skipped > 0 {
        report_pairs.push((
            "staleness",
            json::obj(vec![
                ("appends", json::num(stale_appends as f64)),
                ("skipped", json::num(stale_skipped as f64)),
                ("max_rel_sigma_err", json::num(stale_max_rel)),
                ("tolerance", json::num(STALENESS_TOL)),
                ("within_tolerance", Json::Bool(stale_ok)),
                ("per_append", json::arr(stale_entries)),
            ]),
        ));
    }
    report_pairs.push(("runs", runs_json));
    let report = json::obj(report_pairs);

    // Write the report BEFORE gating so a failed gate still leaves the
    // evidence on disk.
    if let Some(p) = out {
        let mut text = json::write(&report);
        text.push('\n');
        std::fs::write(p, text).map_err(|e| Error::Io { path: p.to_string(), source: e })?;
    }

    if !deterministic {
        return Err(Error::InvalidParam(format!(
            "replay determinism violated: jobs {mismatched:?} returned different \
             singular-value bit patterns across repeat runs at {} threads",
            pool::num_threads()
        )));
    }
    if !stale_ok {
        return Err(Error::InvalidParam(format!(
            "replay staleness violated: worst relative σ error {stale_max_rel:.3e} across \
             {stale_appends} appends exceeds {STALENESS_TOL:.0e} against the from-scratch \
             reference"
        )));
    }
    if std::env::var("BENCH_ASSERT_REUSE").map(|v| v == "1").unwrap_or(false) {
        assert_reuse_gates(&counters)?;
    }

    Ok(ReplaySummary {
        runs: repeat,
        jobs_per_run: jobs.len(),
        counters,
        deterministic,
        wall_secs,
        staleness_appends: stale_appends,
        staleness_max_rel: stale_max_rel,
        staleness_ok: stale_ok,
    })
}

/// From-scratch reference for one audited append: re-materialize the
/// stream prefix (the leading `cum_cols` columns of the dense
/// generative operand), solve it with the value-level LancSVD on a
/// fresh CPU backend, and return the worst relative σ error of the
/// served snapshot against it (normalized by the reference σ₁).
fn staleness_reference(
    dn: &Json,
    cum_cols: usize,
    params: &Params,
    served: &[f64],
) -> Result<f64> {
    let m = req_usize(dn, "m")?;
    let n = req_usize(dn, "n")?;
    let seed = opt_u64(dn, "seed").unwrap_or(42);
    let a = paper_dense(m, n, seed).a;
    let prefix = a.panel_owned(0, cum_cols.min(n));
    let mut be = CpuBackend::new_dense(prefix);
    let svd = lancsvd(
        &mut be,
        &LancSvdOpts {
            r: params.r,
            p: params.p,
            b: params.b,
            seed: params.seed,
            init: InitDist::CenteredPoisson,
            tol: params.tol,
            wanted: params.wanted,
            restart: params.restart,
            fuse: None,
        },
    )?;
    let s1 = svd.sigma.first().copied().unwrap_or(1.0).max(1e-300);
    let mut rel: f64 = 0.0;
    for i in 0..served.len().min(svd.sigma.len()) {
        rel = rel.max((served[i] - svd.sigma[i]).abs() / s1);
    }
    Ok(rel)
}

/// The CI `serve-stress` reuse contract: the warm paths actually ran,
/// admission control actually rejected something, and nothing was
/// rebuilt or failed behind the scenes.
pub fn assert_reuse_gates(c: &ServeCounters) -> Result<()> {
    let mut violations = Vec::new();
    if c.operand_hits == 0 {
        violations.push("expected ≥1 operand-cache hit".to_string());
    }
    if c.ws_warm_reuses == 0 {
        violations.push("expected ≥1 warm workspace reuse".to_string());
    }
    if c.rejected_backpressure + c.rejected_deadline == 0 {
        violations.push("expected ≥1 exercised rejection".to_string());
    }
    if c.operand_rework != 0 {
        violations.push(format!("expected zero operand rework, saw {}", c.operand_rework));
    }
    if c.failed != 0 {
        violations.push(format!("expected zero failed jobs, saw {}", c.failed));
    }
    if violations.is_empty() {
        Ok(())
    } else {
        Err(Error::InvalidParam(format!(
            "serve reuse gates failed: {} (counters: {c:?})",
            violations.join("; ")
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> Params {
        Params { r: 8, p: 2, b: 4, seed: 7, wanted: 4, ..Params::default() }
    }

    fn tiny_operand() -> Operand<f64> {
        Operand::sparse(generate(&SparseSpec {
            rows: 120,
            cols: 48,
            nnz: 1500,
            seed: 3,
            ..SparseSpec::default()
        }))
    }

    #[test]
    fn shape_class_label_and_plan() {
        let spec = JobSpec::new("a", Algo::Lanc, tiny_params(), tiny_operand());
        let c = ShapeClass::of(&spec);
        assert_eq!(c.label(), "lanc:120x48:r8:p2:b4:f64");
        assert_eq!(c.plan().kind, PlanKind::LancSvd);
        let rand = JobSpec::new("b", Algo::Rand, tiny_params(), tiny_operand());
        assert_eq!(ShapeClass::of(&rand).plan().kind, PlanKind::RandSvd);
        let app = JobSpec::append("c", "s", tiny_params(), tiny_operand(), 8);
        let c = ShapeClass::of(&app);
        assert_eq!(c.label(), "inc:120x48:r8:p2:b4:f64");
        assert_eq!(c.plan().kind, PlanKind::Incremental);
        let qry = JobSpec::query("d", "s", tiny_params(), tiny_operand());
        assert_eq!(ShapeClass::of(&qry), c, "append and query share the stream's class");
    }

    #[test]
    fn stream_append_query_finalize_cycle() {
        let params = Params { r: 6, p: 2, b: 3, seed: 7, wanted: 4, ..Params::default() };
        let op = Operand::dense(paper_dense(40, 12, 5).a);
        let mut server = Server::new(ServeConfig { solvers: 1, ..ServeConfig::default() });

        let a1 = server.submit(JobSpec::append("a1", "s", params.clone(), op.clone(), 6)).wait();
        assert_eq!(a1.status, JobStatus::Done, "{:?}", a1.status);
        assert_eq!(a1.cols_seen, 6);
        assert!(!a1.operand_hit, "first append is the stream's one miss");
        assert_eq!(a1.sigma.len(), 4);
        assert!(a1.sigma.windows(2).all(|w| w[0] >= w[1]), "descending {:?}", a1.sigma);

        let q1 = server.submit(JobSpec::query("q1", "s", params.clone(), op.clone())).wait();
        assert_eq!(q1.status, JobStatus::Done, "{:?}", q1.status);
        assert!(q1.operand_hit, "query lands on the warm basis");
        assert_eq!(q1.cols_seen, 6);
        assert_eq!(
            q1.sigma.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            a1.sigma.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "query reads exactly the post-append snapshot"
        );

        let a2 = server.submit(JobSpec::append("a2", "s", params.clone(), op.clone(), 6)).wait();
        assert_eq!(a2.status, JobStatus::Done, "{:?}", a2.status);
        assert_eq!(a2.cols_seen, 12);
        assert!(a2.operand_hit && a2.workspace_warm, "second append reuses basis and arena");

        let f = server.submit(JobSpec::finalize("f", "s", params.clone(), op.clone())).wait();
        assert_eq!(f.status, JobStatus::Done, "{:?}", f.status);
        assert_eq!(f.cols_seen, 12);

        // The tenant is retired: a fresh same-name append is a clean
        // miss (not rework), and a query now has nothing to read.
        let a3 = server.submit(JobSpec::append("a3", "s", params.clone(), op.clone(), 6)).wait();
        assert_eq!(a3.status, JobStatus::Done, "{:?}", a3.status);
        assert_eq!(a3.cols_seen, 6);
        assert!(!a3.operand_hit, "finalize must reset the slot to a clean miss");
        server.shutdown();
        let c = server.counters();
        assert_eq!(c.operand_rework, 0, "{c:?}");
        assert_eq!(c.stream_appends, 3);
        assert_eq!(c.stream_queries, 1);
    }

    #[test]
    fn stream_protocol_parse_validates() {
        let st = ProtocolState::new();
        let defaults = JobDefaults::default();
        let op = r#""operand": {"dense": {"m": 30, "n": 10, "seed": 1}}"#;
        let ok = json::parse(&format!(
            r#"{{"id": "a", "kind": "append", "stream": "s", "cols": 4, {op}}}"#
        ))
        .unwrap();
        let spec = job_from_json(&ok, &defaults, &st).unwrap();
        assert_eq!(spec.kind, JobKind::Append);
        assert_eq!(spec.stream.as_deref(), Some("s"));
        assert_eq!(spec.append_cols, 4);

        let no_stream =
            json::parse(&format!(r#"{{"id": "b", "kind": "query", {op}}}"#)).unwrap();
        assert!(job_from_json(&no_stream, &defaults, &st).is_err());
        let no_cols = json::parse(&format!(
            r#"{{"id": "c", "kind": "append", "stream": "s", {op}}}"#
        ))
        .unwrap();
        assert!(job_from_json(&no_cols, &defaults, &st).is_err());
        let bad_kind = json::parse(&format!(
            r#"{{"id": "d", "kind": "nope", "stream": "s", {op}}}"#
        ))
        .unwrap();
        assert!(job_from_json(&bad_kind, &defaults, &st).is_err());
    }

    #[test]
    fn single_job_end_to_end() {
        let mut server = Server::new(ServeConfig { solvers: 1, ..ServeConfig::default() });
        let r = server
            .submit(JobSpec::new("q", Algo::Lanc, tiny_params(), tiny_operand()))
            .wait();
        assert_eq!(r.status, JobStatus::Done, "{:?}", r.status);
        assert_eq!(r.sigma.len(), 4);
        assert!(r.sigma.windows(2).all(|w| w[0] >= w[1]), "descending {:?}", r.sigma);
        server.shutdown();
        let c = server.counters();
        assert_eq!((c.submitted, c.completed, c.failed), (1, 1, 0));
        assert_eq!(c.operand_misses, 1);
    }

    #[test]
    fn same_operand_hits_same_workspace_warms() {
        let mut server = Server::new(ServeConfig { solvers: 1, ..ServeConfig::default() });
        let op = tiny_operand();
        let a = server.submit(JobSpec::new("a", Algo::Lanc, tiny_params(), op.clone())).wait();
        let b = server.submit(JobSpec::new("b", Algo::Lanc, tiny_params(), op)).wait();
        assert_eq!(a.status, JobStatus::Done);
        assert_eq!(b.status, JobStatus::Done);
        assert!(!a.operand_hit && !a.workspace_warm);
        assert!(b.operand_hit, "second same-operand job must hit the cache");
        assert!(b.workspace_warm, "second same-class job must reuse the arena");
        assert_eq!(a.sigma.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   b.sigma.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        server.shutdown();
    }

    #[test]
    fn zero_deadline_rejects_at_admission() {
        let mut server = Server::new(ServeConfig { solvers: 1, ..ServeConfig::default() });
        let mut spec = JobSpec::new("late", Algo::Lanc, tiny_params(), tiny_operand());
        spec.deadline = Some(Duration::ZERO);
        let r = server.submit(spec).wait();
        assert!(matches!(r.status, JobStatus::Rejected(_)), "{:?}", r.status);
        server.shutdown();
        assert_eq!(server.counters().rejected_deadline, 1);
        assert_eq!(server.counters().completed, 0);
    }

    #[test]
    fn protocol_roundtrip_and_bad_line() {
        let mut server = Server::new(ServeConfig { solvers: 2, ..ServeConfig::default() });
        let defaults = JobDefaults {
            algo: Algo::Lanc,
            params: Params { r: 8, p: 2, b: 4, wanted: 3, ..Params::default() },
        };
        let input = concat!(
            r#"{"id": "p1", "operand": {"sparse": {"rows": 100, "cols": 40, "nnz": 900, "seed": 5}}}"#,
            "\n",
            "this is not json\n",
            r#"{"id": "p2", "algo": "rand", "operand": {"sparse": {"rows": 100, "cols": 40, "nnz": 900, "seed": 5}}}"#,
            "\n",
        );
        let mut out = Vec::new();
        serve_lines(&server, &defaults, input, &mut out).unwrap();
        server.shutdown();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        let mut ok = 0;
        let mut failed = 0;
        for l in &lines {
            let v = json::parse(l).unwrap();
            match v.get("status").unwrap().as_str().unwrap() {
                "ok" => ok += 1,
                "failed" => failed += 1,
                other => panic!("unexpected status {other}"),
            }
        }
        assert_eq!((ok, failed), (2, 1), "{text}");
    }
}
