//! Host `Mat` (column-major) ↔ XLA `Literal` (row-major) conversion and
//! the zero-padding helpers used by the shape-bucketing executable cache.
//!
//! Padding safety: every device graph we ship is exact under zero padding
//! — zero *rows* are no-ops for Gram/projection/update/GEMM, zero
//! *columns* of the history panel P produce zero rows of H, and zero
//! columns of GEMM operands produce zero output columns that the
//! unpadding step drops. This is asserted bitwise in the python kernel
//! tests and revalidated by the backend-parity integration tests.

use crate::error::Result;
use crate::la::mat::{Mat, MatRef};
use crate::util::scalar::Scalar;

/// Column-major Mat → row-major flat buffer.
pub fn to_row_major(m: &Mat) -> Vec<f64> {
    let (r, c) = (m.rows(), m.cols());
    let src = m.data();
    let mut out = vec![0.0; r * c];
    for j in 0..c {
        let col = &src[j * r..(j + 1) * r];
        for i in 0..r {
            out[i * c + j] = col[i];
        }
    }
    out
}

/// Row-major flat buffer → column-major Mat.
pub fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Mat {
    assert_eq!(data.len(), rows * cols);
    let mut m = Mat::zeros(rows, cols);
    let dst = m.data_mut();
    for i in 0..rows {
        for j in 0..cols {
            dst[j * rows + i] = data[i * cols + j];
        }
    }
    m
}

/// Mat → row-major XLA literal of shape [rows, cols], with optional
/// zero padding to [pad_rows, pad_cols].
pub fn mat_to_literal(m: &Mat, pad_rows: usize, pad_cols: usize) -> Result<xla::Literal> {
    matref_to_literal(m.as_ref(), pad_rows, pad_cols)
}

/// [`mat_to_literal`] over a borrowed view — the staging copy into the
/// literal is unavoidable (layout transpose + padding), but the source
/// panel is only read, so callers with `MatRef`/`MatMut` views (the
/// out-parameter backend ops) stage without first materializing an
/// owned `Mat`.
pub fn matref_to_literal(m: MatRef<'_>, pad_rows: usize, pad_cols: usize) -> Result<xla::Literal> {
    matref_to_literal_s(m, pad_rows, pad_cols)
}

/// Generic-precision [`matref_to_literal`]: the staged literal is always
/// f64 (the interchange precision of the AOT artifacts), so an `S = f32`
/// view rounds up during the unavoidable padding/layout copy — no extra
/// pass over the data.
pub fn matref_to_literal_s<S: Scalar>(
    m: MatRef<'_, S>,
    pad_rows: usize,
    pad_cols: usize,
) -> Result<xla::Literal> {
    let (r, c) = (m.rows, m.cols);
    assert!(pad_rows >= r && pad_cols >= c, "padding must not truncate");
    let mut buf = vec![0.0f64; pad_rows * pad_cols];
    for j in 0..c {
        let col = m.col(j);
        for i in 0..r {
            buf[i * pad_cols + j] = col[i].to_f64();
        }
    }
    let lit = xla::Literal::vec1(&buf).reshape(&[pad_rows as i64, pad_cols as i64])?;
    Ok(lit)
}

/// Row-major literal of shape [pr, pc] → Mat, keeping the leading
/// rows×cols corner (the unpadding step).
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    literal_to_mat_s(lit, rows, cols)
}

/// Generic-precision [`literal_to_mat`]: rounds the f64 interchange
/// literal down to `S` during the unpadding copy.
pub fn literal_to_mat_s<S: Scalar>(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat<S>> {
    let shape = lit.array_shape()?;
    let dims = shape.dims();
    assert_eq!(dims.len(), 2, "expected rank-2 literal");
    let (pr, pc) = (dims[0] as usize, dims[1] as usize);
    assert!(pr >= rows && pc >= cols, "literal smaller than requested corner");
    let data = lit.to_vec::<f64>()?;
    let mut m = Mat::zeros(rows, cols);
    let dst = m.data_mut();
    for i in 0..rows {
        for j in 0..cols {
            dst[j * rows + i] = S::from_f64(data[i * pc + j]);
        }
    }
    Ok(m)
}

/// Next power-of-two bucket in [lo, hi] covering x (clamped to hi).
pub fn pow2_bucket(x: usize, lo: usize, hi: usize) -> usize {
    let mut v = lo;
    while v < x && v < hi {
        v *= 2;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn row_major_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(7, 4, &mut rng);
        let rm = to_row_major(&m);
        assert_eq!(rm[0 * 4 + 2], m.at(0, 2));
        let back = from_row_major(7, 4, &rm);
        assert_eq!(back, m);
    }

    #[test]
    fn pow2_bucket_behaviour() {
        assert_eq!(pow2_bucket(500, 512, 65536), 512);
        assert_eq!(pow2_bucket(513, 512, 65536), 1024);
        assert_eq!(pow2_bucket(512, 512, 65536), 512);
        assert_eq!(pow2_bucket(1 << 30, 512, 65536), 65536);
    }

    #[test]
    fn generic_literal_roundtrip_f32() {
        let mut rng = Rng::new(9);
        let m: Mat<f32> = Mat::randn(6, 3, &mut rng);
        let lit = matref_to_literal_s(m.as_ref(), 8, 4).unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[8, 4]);
        let back: Mat<f32> = literal_to_mat_s(&lit, 6, 3).unwrap();
        // f32 → f64 → f32 is exact.
        assert_eq!(back.max_abs_diff(&m), 0.0);
        // And the f64 view of the same literal carries only the f32 value.
        let wide: Mat<f64> = literal_to_mat_s(&lit, 6, 3).unwrap();
        assert!(wide.max_abs_diff(&m.cast()) == 0.0);
    }

    #[test]
    fn literal_roundtrip_with_padding() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(5, 3, &mut rng);
        let lit = mat_to_literal(&m, 8, 4).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[8, 4]);
        let back = literal_to_mat(&lit, 5, 3).unwrap();
        assert!(back.max_abs_diff(&m) == 0.0);
        // padded region is zero: full corner read includes zeros
        let full = literal_to_mat(&lit, 8, 4).unwrap();
        assert_eq!(full.at(7, 3), 0.0);
        assert_eq!(full.at(0, 0), m.at(0, 0));
    }
}
