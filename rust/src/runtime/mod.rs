//! PJRT runtime: artifact manifest, executable cache, and execution.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — the id-safe interchange with xla_extension 0.5.1),
//! compiles them lazily on the PJRT CPU client, and caches the loaded
//! executables keyed by (op, input shapes). Shapes with no artifact can be
//! synthesized at runtime for the plain GEMM ops via `builder_ops`
//! (XlaBuilder — still no python on the request path).

pub mod builder_ops;
pub mod convert;
pub mod serve;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub op: String,
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

fn shape_key(op: &str, shapes: &[&[usize]]) -> String {
    let mut k = String::from(op);
    for s in shapes {
        k.push('|');
        for (i, d) in s.iter().enumerate() {
            if i > 0 {
                k.push('x');
            }
            k.push_str(&d.to_string());
        }
    }
    k
}

/// The PJRT runtime handle. Not `Sync` (PJRT types are single-threaded
/// here); the coordinator owns exactly one.
///
/// `client` is `None` for a [`Runtime::host_only`] runtime: every
/// device-side entry point reports unavailable, so the backends degrade
/// to their host-substrate fallbacks. This is how the XLA backend's
/// fallback ("stub") paths are exercised in environments with no PJRT
/// plugin at all — e.g. the cross-backend conformance suite.
pub struct Runtime {
    client: Option<xla::PjRtClient>,
    dir: String,
    manifest: HashMap<String, ArtifactEntry>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    builder_cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// statistics: (artifact hits, builder-fallback hits, compiles)
    stats: RefCell<RuntimeStats>,
}

/// Cache/compile counters (exposed for tests and the perf report).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub artifact_execs: u64,
    pub builder_execs: u64,
    pub compiles: u64,
}

impl Runtime {
    /// Create a runtime over an artifact directory (with manifest.json).
    pub fn new(artifact_dir: &str) -> Result<Runtime> {
        let client = Some(xla::PjRtClient::cpu()?);
        let mut manifest = HashMap::new();
        let man_path = format!("{artifact_dir}/manifest.json");
        if std::path::Path::new(&man_path).exists() {
            let doc = json::parse_file(&man_path)?;
            for e in doc.req("artifacts")?.as_arr().unwrap_or(&[]) {
                let entry = parse_entry(e)?;
                let shapes: Vec<&[usize]> = entry.inputs.iter().map(|v| v.as_slice()).collect();
                manifest.insert(shape_key(&entry.op, &shapes), entry);
            }
        }
        Ok(Runtime {
            client,
            dir: artifact_dir.to_string(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            builder_cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Create a runtime with *no* artifacts (builder fallback only).
    pub fn without_artifacts() -> Result<Runtime> {
        Ok(Runtime {
            client: Some(xla::PjRtClient::cpu()?),
            dir: String::new(),
            manifest: HashMap::new(),
            cache: RefCell::new(HashMap::new()),
            builder_cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Create a runtime with no PJRT client and no artifacts: every
    /// device entry point reports unavailable and the backends fall back
    /// to the host substrate. Always constructible, even against the
    /// offline `xla_stub` crate — the conformance suite uses this to
    /// drive the XLA backend's fallback paths deterministically.
    pub fn host_only() -> Runtime {
        Runtime {
            client: None,
            dir: String::new(),
            manifest: HashMap::new(),
            cache: RefCell::new(HashMap::new()),
            builder_cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        }
    }

    fn client_ref(&self) -> Result<&xla::PjRtClient> {
        // `Error::Xla` so the backends treat it as "runtime unavailable"
        // and degrade to their host fallbacks.
        self.client
            .as_ref()
            .ok_or_else(|| Error::Xla("host-only runtime: no PJRT client".into()))
    }

    /// Does this runtime have a live PJRT client?
    pub fn has_client(&self) -> bool {
        self.client.is_some()
    }

    /// PJRT platform name, or "host-only" when no client exists.
    pub fn platform_name(&self) -> String {
        match &self.client {
            Some(c) => c.platform_name(),
            None => "host-only".to_string(),
        }
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    pub fn artifact_count(&self) -> usize {
        self.manifest.len()
    }

    /// Does the manifest have an artifact for these exact (padded) shapes?
    pub fn has_artifact(&self, op: &str, shapes: &[&[usize]]) -> bool {
        self.manifest.contains_key(&shape_key(op, shapes))
    }

    /// Compile (or fetch from cache) the artifact executable.
    pub fn artifact_exec(
        &self,
        op: &str,
        shapes: &[&[usize]],
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = shape_key(op, shapes);
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let entry = self.manifest.get(&key).ok_or_else(|| Error::MissingArtifact {
            op: op.to_string(),
            shape: key.clone(),
        })?;
        let path = format!("{}/{}", self.dir, entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client_ref()?.compile(&comp)?);
        self.stats.borrow_mut().compiles += 1;
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with literal inputs; returns the decomposed
    /// tuple outputs (every artifact is lowered with return_tuple=True).
    pub fn run_artifact(
        &self,
        op: &str,
        shapes: &[&[usize]],
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.artifact_exec(op, shapes)?;
        self.stats.borrow_mut().artifact_execs += 1;
        let out = exe.execute::<xla::Literal>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute an artifact where some inputs are device-resident buffers.
    pub fn run_artifact_b(
        &self,
        op: &str,
        shapes: &[&[usize]],
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.artifact_exec(op, shapes)?;
        self.stats.borrow_mut().artifact_execs += 1;
        let out = exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Stage a host literal into a device buffer (for persistent operands
    /// like the problem matrix A).
    pub fn stage(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client_ref()?.buffer_from_host_literal(None, lit)?)
    }

    /// Fetch (compile-once) a runtime-built executable; `build` constructs
    /// the computation on a fresh XlaBuilder when not cached.
    pub fn builder_exec(
        &self,
        key: String,
        build: impl FnOnce() -> Result<xla::XlaComputation>,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.builder_cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let comp = build()?;
        let exe = Rc::new(self.client_ref()?.compile(&comp)?);
        self.stats.borrow_mut().compiles += 1;
        self.builder_cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Count a builder-path execution (called by builder_ops).
    pub(crate) fn note_builder_exec(&self) {
        self.stats.borrow_mut().builder_execs += 1;
    }
}

fn parse_entry(e: &Json) -> Result<ArtifactEntry> {
    let shapes = |v: &Json| -> Vec<Vec<usize>> {
        v.as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|s| s.as_arr().unwrap_or(&[]).iter().filter_map(|d| d.as_usize()).collect())
            .collect()
    };
    Ok(ArtifactEntry {
        op: e.req("op")?.as_str().unwrap_or("").to_string(),
        file: e.req("file")?.as_str().unwrap_or("").to_string(),
        inputs: shapes(e.req("inputs")?),
        outputs: shapes(e.req("outputs")?),
    })
}

/// Default artifact directory: `$TRUNKSVD_ARTIFACTS`, else ./artifacts,
/// else the crate-root artifacts dir.
pub fn default_artifact_dir() -> String {
    if let Ok(p) = std::env::var("TRUNKSVD_ARTIFACTS") {
        return p;
    }
    if std::path::Path::new("artifacts/manifest.json").exists() {
        return "artifacts".to_string();
    }
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_key_stable() {
        let a = [4usize, 5];
        let b = [5usize];
        assert_eq!(shape_key("op", &[&a, &b]), "op|4x5|5");
        assert_eq!(shape_key("op", &[]), "op");
    }

    #[test]
    fn host_only_runtime_fails_soft() {
        let rt = Runtime::host_only();
        assert!(!rt.has_client());
        assert_eq!(rt.platform_name(), "host-only");
        assert_eq!(rt.artifact_count(), 0);
        let q = [512usize, 16];
        assert!(!rt.has_artifact("cholqr2", &[&q]));
        // Every device entry point reports an Xla-class error (the
        // signal the backends treat as "degrade to host").
        match rt.artifact_exec("cholqr2", &[&q]) {
            Err(Error::MissingArtifact { .. }) | Err(Error::Xla(_)) => {}
            other => panic!("expected unavailable, got {:?}", other.is_ok()),
        }
        let lit = xla::Literal::vec1(&[0.0f64; 4]);
        assert!(matches!(rt.stage(&lit), Err(Error::Xla(_))));
    }

    #[test]
    fn manifest_parses_if_present() {
        let dir = default_artifact_dir();
        if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
            let rt = Runtime::new(&dir).unwrap();
            assert!(rt.artifact_count() > 0);
            let q = [512usize, 16];
            assert!(rt.has_artifact("cholqr2", &[&q]));
        }
    }
}
