//! Runtime-built XLA computations (the fallback for shapes without an AOT
//! artifact). Python stays off the request path: the computations are
//! assembled with the `xla` crate's XlaBuilder and cached per shape.

use std::rc::Rc;

use super::convert::{literal_to_mat_s, matref_to_literal_s};
use super::Runtime;
use crate::error::Result;
use crate::la::mat::Mat;
use crate::util::scalar::Scalar;

fn f64_shape(dims: &[usize]) -> xla::Shape {
    xla::Shape::array::<f64>(dims.iter().map(|&d| d as i64).collect())
}

fn build_matmul_nn(m: usize, k: usize, n: usize) -> Result<xla::XlaComputation> {
    let b = xla::XlaBuilder::new("matmul_nn");
    let a = b.parameter_s(0, &f64_shape(&[m, k]), "a")?;
    let x = b.parameter_s(1, &f64_shape(&[k, n]), "x")?;
    Ok(a.matmul(&x)?.build()?)
}

fn build_matmul_tn(q: usize, a_cols: usize, b_cols: usize) -> Result<xla::XlaComputation> {
    let b = xla::XlaBuilder::new("matmul_tn");
    let a = b.parameter_s(0, &f64_shape(&[q, a_cols]), "a")?;
    let x = b.parameter_s(1, &f64_shape(&[q, b_cols]), "x")?;
    let at = a.transpose(&[1, 0])?;
    Ok(at.matmul(&x)?.build()?)
}

/// C = A·B through a runtime-built, cached executable. Generic over the
/// caller's element precision; the device graph runs at the f64
/// interchange precision (values round through the literal staging).
pub fn matmul_nn<S: Scalar>(rt: &Runtime, a: &Mat<S>, b: &Mat<S>) -> Result<Mat<S>> {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k, "matmul_nn inner dim");
    let exe = rt.builder_exec(format!("bnn|{m}x{k}x{n}"), || build_matmul_nn(m, k, n))?;
    run2(rt, &exe, a, b, m, n)
}

/// C = Aᵀ·B through a runtime-built, cached executable (precision
/// semantics as [`matmul_nn`]).
pub fn matmul_tn<S: Scalar>(rt: &Runtime, a: &Mat<S>, b: &Mat<S>) -> Result<Mat<S>> {
    let (q, ac) = (a.rows(), a.cols());
    let bc = b.cols();
    assert_eq!(b.rows(), q, "matmul_tn inner dim");
    let exe = rt.builder_exec(format!("btn|{q}x{ac}x{bc}"), || build_matmul_tn(q, ac, bc))?;
    run2(rt, &exe, a, b, ac, bc)
}

fn run2<S: Scalar>(
    rt: &Runtime,
    exe: &Rc<xla::PjRtLoadedExecutable>,
    a: &Mat<S>,
    b: &Mat<S>,
    out_rows: usize,
    out_cols: usize,
) -> Result<Mat<S>> {
    let la = matref_to_literal_s(a.as_ref(), a.rows(), a.cols())?;
    let lb = matref_to_literal_s(b.as_ref(), b.rows(), b.cols())?;
    rt.note_builder_exec();
    let out = exe.execute::<xla::Literal>(&[la, lb])?;
    let lit = out[0][0].to_literal_sync()?;
    literal_to_mat_s(&lit, out_rows, out_cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::la::blas3::{mat_nn, mat_tn};
    use crate::util::rng::Rng;

    #[test]
    fn builder_matmuls_match_cpu() {
        // Skip when no PJRT client can be created (offline stub build).
        let Ok(rt) = Runtime::without_artifacts() else {
            eprintln!("SKIP: no PJRT client (stub xla build)");
            return;
        };
        let mut rng = Rng::new(3);
        let a = Mat::randn(17, 9, &mut rng);
        let b = Mat::randn(9, 5, &mut rng);
        let c = matmul_nn(&rt, &a, &b).unwrap();
        assert!(c.max_abs_diff(&mat_nn(&a, &b)) < 1e-12);
        let x = Mat::randn(17, 4, &mut rng);
        let h = matmul_tn(&rt, &a, &x).unwrap();
        assert!(h.max_abs_diff(&mat_tn(&a, &x)) < 1e-12);
        // second call hits the cache (one compile per shape)
        let _ = matmul_nn(&rt, &a, &b).unwrap();
        let stats = rt.stats();
        assert_eq!(stats.compiles, 2);
        assert_eq!(stats.builder_execs, 3);
    }
}
