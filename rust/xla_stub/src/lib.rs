//! Host-side stub of the `xla` (xla_extension 0.5.1 / xla-rs) bindings.
//!
//! The offline build environment ships no libxla, so this crate provides
//! the exact API surface `trunksvd` uses with two behavior classes:
//!
//! * **Host literal/shape types are real**: [`Literal`], [`ArrayShape`],
//!   and [`Shape`] implement the value semantics the runtime's
//!   `Mat ↔ Literal` conversion layer relies on (vec1/reshape/to_vec),
//!   so that layer stays fully testable without a device runtime.
//! * **PJRT / builder entry points fail fast**: [`PjRtClient::cpu`],
//!   [`HloModuleProto::from_text_file`], and [`XlaBuilder::parameter_s`]
//!   return an [`Error`], which the trunksvd backends already treat as
//!   "runtime unavailable" and degrade to the CPU substrate.
//!
//! Swapping this path dependency for the real bindings re-enables the
//! PJRT path with no source changes in trunksvd.

use std::fmt;

/// Stub error: every device-side operation reports unavailable.
#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: xla runtime not available (stub build; link the real xla_extension bindings to enable PJRT)"
    )))
}

/// Element storage for stub literals (only the types trunksvd stages).
#[derive(Clone, Debug, PartialEq)]
enum Payload {
    F64(Vec<f64>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F64(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for i32 {}
}

/// Native element types a [`Literal`] can hold.
pub trait NativeType: sealed::Sealed + Copy {
    #[doc(hidden)]
    fn stub_store(data: &[Self]) -> Literal;
    #[doc(hidden)]
    fn stub_extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f64 {
    fn stub_store(data: &[Self]) -> Literal {
        Literal { payload: Payload::F64(data.to_vec()), dims: vec![data.len() as i64] }
    }
    fn stub_extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::F64(v) => Ok(v.clone()),
            _ => unavailable("Literal::to_vec::<f64> on non-f64 literal"),
        }
    }
}

impl NativeType for i32 {
    fn stub_store(data: &[Self]) -> Literal {
        Literal { payload: Payload::I32(data.to_vec()), dims: vec![data.len() as i64] }
    }
    fn stub_extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            _ => unavailable("Literal::to_vec::<i32> on non-i32 literal"),
        }
    }
}

/// A host tensor value (fully functional in the stub).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::stub_store(data)
    }

    /// Same payload with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.payload.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.payload.len()
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Dense array shape of this literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::stub_extract(self)
    }

    /// Decompose a tuple literal (only produced by device execution,
    /// which the stub cannot perform).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Dims of a dense array literal.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A (typed) array shape used to declare computation parameters.
#[derive(Clone, Debug)]
pub struct Shape {
    #[allow(dead_code)]
    dims: Vec<i64>,
}

impl Shape {
    pub fn array<T: NativeType>(dims: Vec<i64>) -> Shape {
        Shape { dims }
    }
}

/// PJRT client handle (creation always fails in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Compiled executable handle (unreachable in the stub: no client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Device buffer handle (unreachable in the stub: no client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module (text loading requires the real bindings).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (opaque in the stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Computation builder (parameter creation fails in the stub, so every
/// builder-constructed graph degrades to the caller's CPU fallback).
pub struct XlaBuilder {
    _name: String,
}

impl XlaBuilder {
    pub fn new(name: &str) -> XlaBuilder {
        XlaBuilder { _name: name.to_string() }
    }

    pub fn parameter_s(&self, _id: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        unavailable("XlaBuilder::parameter_s")
    }
}

/// A node in a computation under construction.
pub struct XlaOp {
    _private: (),
}

impl XlaOp {
    pub fn matmul(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        unavailable("XlaOp::matmul")
    }

    pub fn transpose(&self, _perm: &[i64]) -> Result<XlaOp> {
        unavailable("XlaOp::transpose")
    }

    pub fn build(&self) -> Result<XlaComputation> {
        unavailable("XlaOp::build")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f64() {
        let l = Literal::vec1(&[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[7i32, 8]).reshape(&[1, 2]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn pjrt_is_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("/nope.hlo").is_err());
        let b = XlaBuilder::new("t");
        assert!(b.parameter_s(0, &Shape::array::<f64>(vec![2, 2]), "a").is_err());
    }
}
