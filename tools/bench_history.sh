#!/usr/bin/env bash
# Append a compact summary of a bench/replay report to the committed
# perf trajectory (BENCH_history/trajectory.jsonl) and fail the run if a
# deterministic metric regressed against the last committed entry.
#
#   tools/bench_history.sh [REPORT.json] [BENCH_history/trajectory.jsonl]
#
# Two report shapes are recognized:
#   - BENCH_kernels.json (kernel micro-bench): the default.
#   - serve-replay reports carrying a `staleness` section (streaming
#     workloads, e.g. BENCH_serve_streaming.json): appended as a
#     `kind: "serve-stream"` entry.
#
# Two classes of metric:
#   - deterministic (ledger byte counts, pass counts, parity flags,
#     staleness of the incremental basis vs the from-scratch prefix
#     solve): hard-gated. `ooc_disk_drop` must not fall below 0.9x the
#     last committed value, `bitwise_parity` must stay 1,
#     `hot_panel_transfers` must stay 0, and serve-stream entries must
#     be within the staleness tolerance and bitwise repeat-run
#     deterministic.
#   - timing (speedups, overlap efficiency): recorded for trend reading
#     only — CI runners are too noisy to gate on wall-clock ratios here;
#     the bench's own BENCH_ASSERT_* envs gate those at full size.
#
# CI appends on every run and uploads the updated file as an artifact;
# maintainers periodically commit the artifact back so the trajectory in
# the repo tracks merged history (see BENCH_history/README.md).
set -euo pipefail

BENCH=${1:-BENCH_kernels.json}
HIST=${2:-BENCH_history/trajectory.jsonl}

if ! command -v jq >/dev/null 2>&1; then
    echo "bench-history: jq not found; skipping trajectory append" >&2
    exit 0
fi
[ -f "$BENCH" ] || { echo "bench-history: $BENCH not found" >&2; exit 1; }
mkdir -p "$(dirname "$HIST")"

commit=$(git rev-parse --short HEAD 2>/dev/null || echo unknown)
stamp=$(date -u +%Y-%m-%dT%H:%M:%SZ)

# ---- serve-replay reports with a staleness audit (streaming workloads)
if jq -e 'has("staleness")' "$BENCH" >/dev/null; then
    entry=$(jq -c --arg commit "$commit" --arg date "$stamp" '{
        commit: $commit,
        date: $date,
        kind: "serve-stream",
        threads: .threads,
        workers: .workers,
        backend: .backend,
        repeat: .repeat,
        jobs_per_run: .jobs_per_run,
        staleness_appends: .staleness.appends,
        staleness_skipped: .staleness.skipped,
        staleness_max_rel_sigma_err: .staleness.max_rel_sigma_err,
        staleness_tolerance: .staleness.tolerance,
        staleness_within_tolerance: .staleness.within_tolerance,
        deterministic: .determinism.bitwise_identical,
        failed: .counters.failed
    }' "$BENCH")

    stale_ok=$(echo "$entry" | jq -r '.staleness_within_tolerance')
    det=$(echo "$entry" | jq -r '.deterministic')
    failed=$(echo "$entry" | jq -r '.failed')
    if [ "$stale_ok" != "true" ]; then
        echo "bench-history: REGRESSION — incremental basis drifted past the" \
             "staleness tolerance ($(echo "$entry" | jq -r '.staleness_max_rel_sigma_err'))" >&2
        exit 1
    fi
    if [ "$det" != "true" ]; then
        echo "bench-history: REGRESSION — streaming replay lost bitwise repeat-run determinism" >&2
        exit 1
    fi
    if [ "$failed" != "0" ]; then
        echo "bench-history: REGRESSION — $failed failed job(s) in the streaming replay" >&2
        exit 1
    fi

    echo "$entry" >> "$HIST"
    echo "bench-history: appended serve-stream entry -> $HIST"
    echo "$entry" | jq .
    exit 0
fi

# ---- BENCH_kernels.json (kernel micro-bench)
entry=$(jq -c --arg commit "$commit" --arg date "$stamp" '{
    commit: $commit,
    date: $date,
    threads: .threads,
    quick: .quick,
    fused_ata_speedup: .fused.ata_speedup,
    fused_gram_speedup: .fused.gram_speedup,
    fused_ooc_disk_drop: .fused.ooc_disk_drop,
    ooc_passes: .out_of_core.passes,
    ooc_overlap_efficiency: .out_of_core.overlap_efficiency,
    ooc_bitwise_parity: .out_of_core.bitwise_parity,
    ooc_hot_panel_transfers: .out_of_core.hot_panel_transfers,
    parallel_cutoff: .cost_calibration.parallel_cutoff
}' "$BENCH")

# Absolute gates on the fresh run — these never depend on history.
parity=$(echo "$entry" | jq -r '.ooc_bitwise_parity')
hot=$(echo "$entry" | jq -r '.ooc_hot_panel_transfers')
if [ "$parity" != "1" ]; then
    echo "bench-history: REGRESSION — out-of-core bitwise parity lost ($parity)" >&2
    exit 1
fi
if [ "$hot" != "0" ]; then
    echo "bench-history: REGRESSION — $hot hot-loop panel transfers (must be 0)" >&2
    exit 1
fi

# Relative gate vs the last committed kernel entry (serve-stream entries
# interleave in the same file, so filter by shape): the fused tier's
# disk-byte drop is a deterministic ledger ratio, so any real decrease
# is a code change, not noise. Allow 10% slack for bench-shape changes.
last=$(jq -c 'select(has("fused_ooc_disk_drop"))' "$HIST" 2>/dev/null | tail -n 1 || true)
if [ -n "$last" ]; then
    prev_drop=$(echo "$last" | jq -r '.fused_ooc_disk_drop // empty')
    new_drop=$(echo "$entry" | jq -r '.fused_ooc_disk_drop // empty')
    if [ -n "$prev_drop" ] && [ -n "$new_drop" ]; then
        ok=$(jq -n --argjson a "$new_drop" --argjson b "$prev_drop" '$a >= 0.9 * $b')
        if [ "$ok" != "true" ]; then
            echo "bench-history: REGRESSION — fused disk-byte drop $new_drop" \
                 "fell below 0.9x last committed $prev_drop" >&2
            echo "bench-history: last committed entry: $last" >&2
            exit 1
        fi
    fi
fi

echo "$entry" >> "$HIST"
echo "bench-history: appended -> $HIST"
echo "$entry" | jq .
