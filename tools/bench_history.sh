#!/usr/bin/env bash
# Append a compact summary of a BENCH_kernels.json run to the committed
# perf trajectory (BENCH_history/trajectory.jsonl) and fail the run if a
# deterministic metric regressed against the last committed entry.
#
#   tools/bench_history.sh [BENCH_kernels.json] [BENCH_history/trajectory.jsonl]
#
# Two classes of metric:
#   - deterministic (ledger byte counts, pass counts, parity flags):
#     hard-gated. `ooc_disk_drop` must not fall below 0.9x the last
#     committed value, `bitwise_parity` must stay 1, and
#     `hot_panel_transfers` must stay 0.
#   - timing (speedups, overlap efficiency): recorded for trend reading
#     only — CI runners are too noisy to gate on wall-clock ratios here;
#     the bench's own BENCH_ASSERT_* envs gate those at full size.
#
# CI appends on every run and uploads the updated file as an artifact;
# maintainers periodically commit the artifact back so the trajectory in
# the repo tracks merged history (see BENCH_history/README.md).
set -euo pipefail

BENCH=${1:-BENCH_kernels.json}
HIST=${2:-BENCH_history/trajectory.jsonl}

if ! command -v jq >/dev/null 2>&1; then
    echo "bench-history: jq not found; skipping trajectory append" >&2
    exit 0
fi
[ -f "$BENCH" ] || { echo "bench-history: $BENCH not found" >&2; exit 1; }
mkdir -p "$(dirname "$HIST")"

commit=$(git rev-parse --short HEAD 2>/dev/null || echo unknown)
stamp=$(date -u +%Y-%m-%dT%H:%M:%SZ)

entry=$(jq -c --arg commit "$commit" --arg date "$stamp" '{
    commit: $commit,
    date: $date,
    threads: .threads,
    quick: .quick,
    fused_ata_speedup: .fused.ata_speedup,
    fused_gram_speedup: .fused.gram_speedup,
    fused_ooc_disk_drop: .fused.ooc_disk_drop,
    ooc_passes: .out_of_core.passes,
    ooc_overlap_efficiency: .out_of_core.overlap_efficiency,
    ooc_bitwise_parity: .out_of_core.bitwise_parity,
    ooc_hot_panel_transfers: .out_of_core.hot_panel_transfers,
    parallel_cutoff: .cost_calibration.parallel_cutoff
}' "$BENCH")

# Absolute gates on the fresh run — these never depend on history.
parity=$(echo "$entry" | jq -r '.ooc_bitwise_parity')
hot=$(echo "$entry" | jq -r '.ooc_hot_panel_transfers')
if [ "$parity" != "1" ]; then
    echo "bench-history: REGRESSION — out-of-core bitwise parity lost ($parity)" >&2
    exit 1
fi
if [ "$hot" != "0" ]; then
    echo "bench-history: REGRESSION — $hot hot-loop panel transfers (must be 0)" >&2
    exit 1
fi

# Relative gate vs the last committed entry: the fused tier's disk-byte
# drop is a deterministic ledger ratio, so any real decrease is a code
# change, not noise. Allow 10% slack for bench-shape changes.
last=$(grep -v '^\s*$' "$HIST" 2>/dev/null | tail -n 1 || true)
if [ -n "$last" ]; then
    prev_drop=$(echo "$last" | jq -r '.fused_ooc_disk_drop // empty')
    new_drop=$(echo "$entry" | jq -r '.fused_ooc_disk_drop // empty')
    if [ -n "$prev_drop" ] && [ -n "$new_drop" ]; then
        ok=$(jq -n --argjson a "$new_drop" --argjson b "$prev_drop" '$a >= 0.9 * $b')
        if [ "$ok" != "true" ]; then
            echo "bench-history: REGRESSION — fused disk-byte drop $new_drop" \
                 "fell below 0.9x last committed $prev_drop" >&2
            echo "bench-history: last committed entry: $last" >&2
            exit 1
        fi
    fi
fi

echo "$entry" >> "$HIST"
echo "bench-history: appended -> $HIST"
echo "$entry" | jq .
